//! Tolerance-aware baseline diffing — the CI regression gate.
//!
//! [`diff`] matches a current [`BenchReport`] against a committed
//! baseline record-by-record (by name) and classifies every metric using
//! the *baseline's* direction, kind, and relative tolerance (the baseline
//! is the contract; a current run cannot loosen it):
//!
//! * [`DiffStatus::Regressed`] — moved against its [`Direction`] by more
//!   than `rel_tol` (for [`Direction::Exact`] metrics, *any* drift beyond
//!   tolerance regresses, improvements included: predicted == measured
//!   pins must be re-baselined deliberately, not silently absorbed);
//! * [`DiffStatus::Improved`] / [`DiffStatus::Unchanged`] — the benign
//!   outcomes;
//! * [`DiffStatus::Info`] — wall-clock metrics: reported, never gating;
//! * [`DiffStatus::Removed`] — in the baseline, missing from the current
//!   run.  A vanished deterministic metric gates (a silently dropped pin
//!   is a regression of coverage); a vanished wall-clock metric does not;
//! * [`DiffStatus::Added`] — new in the current run; never gates.
//!
//! [`ReportDiff::has_regressions`] is the single bit CI acts on.

use super::{BenchReport, Direction, MetricKind};

/// Classification of one metric in a baseline diff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiffStatus {
    /// Moved in the improving direction by more than the tolerance.
    Improved,
    /// Within tolerance of the baseline.
    Unchanged,
    /// Moved against the metric's direction by more than the tolerance
    /// (or drifted at all, for `Exact` metrics) — gates CI.
    Regressed,
    /// Wall-clock metric: change reported, never gating.
    Info,
    /// Present only in the current run.
    Added,
    /// Present only in the baseline (gates when the baseline record was
    /// deterministic).
    Removed,
}

impl DiffStatus {
    /// Short label for tables.
    pub fn as_str(self) -> &'static str {
        match self {
            DiffStatus::Improved => "improved",
            DiffStatus::Unchanged => "unchanged",
            DiffStatus::Regressed => "REGRESSED",
            DiffStatus::Info => "info",
            DiffStatus::Added => "added",
            DiffStatus::Removed => "REMOVED",
        }
    }
}

/// One metric's baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct DiffEntry {
    /// Metric name.
    pub name: String,
    /// Baseline value (`None` for [`DiffStatus::Added`]).
    pub baseline: Option<f64>,
    /// Current value (`None` for [`DiffStatus::Removed`]).
    pub current: Option<f64>,
    /// Signed relative change `(current - baseline) / |baseline|`
    /// (`None` when either side is missing; `±inf` collapses to the
    /// tolerance comparison when the baseline is exactly zero).
    pub rel_change: Option<f64>,
    /// Relative tolerance the classification used (the baseline's).
    pub rel_tol: f64,
    /// Classification.
    pub status: DiffStatus,
    /// Whether this entry can gate CI (deterministic baseline records).
    pub gated: bool,
}

/// The full diff of one report pair.
#[derive(Debug, Clone)]
pub struct ReportDiff {
    /// Suite name (from the baseline).
    pub suite: String,
    /// Per-metric entries, baseline order first, then added metrics.
    pub entries: Vec<DiffEntry>,
}

impl ReportDiff {
    /// The gating failures: regressed or removed deterministic metrics.
    pub fn regressions(&self) -> Vec<&DiffEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.gated
                    && matches!(
                        e.status,
                        DiffStatus::Regressed | DiffStatus::Removed
                    )
            })
            .collect()
    }

    /// True when any gating metric regressed — the bit CI fails on.
    pub fn has_regressions(&self) -> bool {
        !self.regressions().is_empty()
    }

    /// A printable summary table (one row per non-`Unchanged` entry, plus
    /// a count line; `verbose` includes unchanged rows too).
    pub fn summary(&self, verbose: bool) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut unchanged = 0usize;
        for e in &self.entries {
            if e.status == DiffStatus::Unchanged && !verbose {
                unchanged += 1;
                continue;
            }
            let fmt_side = |v: Option<f64>| match v {
                Some(v) => format!("{v:.6e}"),
                None => "-".to_string(),
            };
            let delta = match e.rel_change {
                Some(d) if d.is_finite() => format!("{:+.4}%", 100.0 * d),
                _ => "-".to_string(),
            };
            let _ = writeln!(
                out,
                "  {:<52} {:>13} -> {:>13}  {:>10}  {}",
                e.name,
                fmt_side(e.baseline),
                fmt_side(e.current),
                delta,
                e.status.as_str()
            );
            if e.status == DiffStatus::Unchanged {
                unchanged += 1;
            }
        }
        let _ = writeln!(
            out,
            "  {} metric(s): {} unchanged, {} regressed/removed (gating), \
             {} informational",
            self.entries.len(),
            unchanged,
            self.regressions().len(),
            self.entries
                .iter()
                .filter(|e| e.status == DiffStatus::Info)
                .count(),
        );
        out
    }
}

/// Diff `current` against `baseline` (see the module docs for the
/// classification rules).  Environment metadata is *not* compared — a
/// baseline generated on a different machine or commit is still a valid
/// contract for the deterministic metrics.
pub fn diff(baseline: &BenchReport, current: &BenchReport) -> ReportDiff {
    let mut entries = Vec::with_capacity(baseline.records.len());
    for b in &baseline.records {
        let gated = b.kind == MetricKind::Deterministic;
        let entry = match current.get(&b.name) {
            None => DiffEntry {
                name: b.name.clone(),
                baseline: Some(b.value),
                current: None,
                rel_change: None,
                rel_tol: b.rel_tol,
                status: DiffStatus::Removed,
                gated,
            },
            Some(c) => {
                let rel = if b.value != 0.0 {
                    (c.value - b.value) / b.value.abs()
                } else if c.value == 0.0 {
                    0.0
                } else {
                    f64::INFINITY.copysign(c.value)
                };
                let status = if b.kind == MetricKind::WallClock {
                    DiffStatus::Info
                } else {
                    classify(b.better, rel, b.rel_tol)
                };
                DiffEntry {
                    name: b.name.clone(),
                    baseline: Some(b.value),
                    current: Some(c.value),
                    rel_change: Some(rel),
                    rel_tol: b.rel_tol,
                    status,
                    gated,
                }
            }
        };
        entries.push(entry);
    }
    for c in &current.records {
        if baseline.get(&c.name).is_none() {
            entries.push(DiffEntry {
                name: c.name.clone(),
                baseline: None,
                current: Some(c.value),
                rel_change: None,
                rel_tol: c.rel_tol,
                status: DiffStatus::Added,
                gated: false,
            });
        }
    }
    ReportDiff { suite: baseline.suite.clone(), entries }
}

fn classify(better: Direction, rel: f64, tol: f64) -> DiffStatus {
    match better {
        Direction::Exact => {
            if rel.abs() <= tol {
                DiffStatus::Unchanged
            } else {
                DiffStatus::Regressed
            }
        }
        Direction::Higher => {
            if rel < -tol {
                DiffStatus::Regressed
            } else if rel > tol {
                DiffStatus::Improved
            } else {
                DiffStatus::Unchanged
            }
        }
        Direction::Lower => {
            if rel > tol {
                DiffStatus::Regressed
            } else if rel < -tol {
                DiffStatus::Improved
            } else {
                DiffStatus::Unchanged
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{BenchEnv, BenchRecord};

    fn env() -> BenchEnv {
        BenchEnv {
            git_rev: "r".into(),
            cpu_count: 1,
            build_profile: "release".into(),
            date: "2026-08-07".into(),
            os: "linux/x86_64".into(),
        }
    }

    fn report(records: Vec<BenchRecord>) -> BenchReport {
        let mut r = BenchReport::new("t", env());
        for rec in records {
            r.push(rec).unwrap();
        }
        r
    }

    fn status_of(d: &ReportDiff, name: &str) -> DiffStatus {
        d.entries.iter().find(|e| e.name == name).unwrap().status
    }

    #[test]
    fn higher_is_better_classification() {
        let base = report(vec![
            BenchRecord::new("ops", 100.0, "ops/s").better(Direction::Higher).tol(0.01)
        ]);
        for (cur, want) in [
            (100.5, DiffStatus::Unchanged),
            (99.5, DiffStatus::Unchanged),
            (102.0, DiffStatus::Improved),
            (98.0, DiffStatus::Regressed),
        ] {
            let c = report(vec![BenchRecord::new("ops", cur, "ops/s")]);
            assert_eq!(status_of(&diff(&base, &c), "ops"), want, "cur={cur}");
        }
    }

    #[test]
    fn lower_is_better_classification() {
        let base = report(vec![
            BenchRecord::new("energy", 10.0, "J").better(Direction::Lower).tol(0.05)
        ]);
        for (cur, want) in [
            (10.2, DiffStatus::Unchanged),
            (9.0, DiffStatus::Improved),
            (11.0, DiffStatus::Regressed),
        ] {
            let c = report(vec![BenchRecord::new("energy", cur, "J")]);
            assert_eq!(status_of(&diff(&base, &c), "energy"), want, "cur={cur}");
        }
    }

    #[test]
    fn exact_pins_regress_in_both_directions() {
        let base = report(vec![BenchRecord::new("cycles", 1000.0, "cycles")]);
        for (cur, want) in [
            (1000.0, DiffStatus::Unchanged),
            (999.0, DiffStatus::Regressed),
            (1001.0, DiffStatus::Regressed),
        ] {
            let c = report(vec![BenchRecord::new("cycles", cur, "cycles")]);
            assert_eq!(status_of(&diff(&base, &c), "cycles"), want, "cur={cur}");
        }
    }

    #[test]
    fn zero_baseline_handled() {
        let base = report(vec![BenchRecord::new("z", 0.0, "x").tol(0.1)]);
        let same = report(vec![BenchRecord::new("z", 0.0, "x")]);
        assert_eq!(status_of(&diff(&base, &same), "z"), DiffStatus::Unchanged);
        let moved = report(vec![BenchRecord::new("z", 0.5, "x")]);
        assert_eq!(status_of(&diff(&base, &moved), "z"), DiffStatus::Regressed);
    }

    #[test]
    fn wall_clock_never_gates() {
        let base = report(vec![
            BenchRecord::new("wall", 1.0, "s").better(Direction::Lower).wall_clock()
        ]);
        let slow = report(vec![BenchRecord::new("wall", 100.0, "s").wall_clock()]);
        let d = diff(&base, &slow);
        assert_eq!(status_of(&d, "wall"), DiffStatus::Info);
        assert!(!d.has_regressions());
        // ... even when it disappears entirely
        let gone = report(vec![]);
        let d = diff(&base, &gone);
        assert_eq!(status_of(&d, "wall"), DiffStatus::Removed);
        assert!(!d.has_regressions());
    }

    #[test]
    fn removed_deterministic_metric_gates() {
        let base = report(vec![BenchRecord::new("pin", 7.0, "x")]);
        let d = diff(&base, &report(vec![]));
        assert_eq!(status_of(&d, "pin"), DiffStatus::Removed);
        assert!(d.has_regressions());
    }

    #[test]
    fn added_metric_does_not_gate() {
        let base = report(vec![]);
        let cur = report(vec![BenchRecord::new("new", 1.0, "x")]);
        let d = diff(&base, &cur);
        assert_eq!(status_of(&d, "new"), DiffStatus::Added);
        assert!(!d.has_regressions());
    }

    #[test]
    fn baseline_tolerance_wins_over_current() {
        // the committed contract can't be loosened by the current run
        let base = report(vec![BenchRecord::new("m", 100.0, "x").tol(0.0)]);
        let cur = report(vec![BenchRecord::new("m", 101.0, "x").tol(10.0)]);
        assert_eq!(status_of(&diff(&base, &cur), "m"), DiffStatus::Regressed);
    }

    #[test]
    fn summary_formats() {
        let base = report(vec![
            BenchRecord::new("a", 1.0, "x"),
            BenchRecord::new("b", 2.0, "x"),
        ]);
        let cur = report(vec![
            BenchRecord::new("a", 1.0, "x"),
            BenchRecord::new("b", 3.0, "x"),
        ]);
        let d = diff(&base, &cur);
        let s = d.summary(false);
        assert!(s.contains("REGRESSED"), "{s}");
        assert!(!s.contains("\n  a "), "unchanged rows hidden: {s}");
        assert!(d.summary(true).contains('a'));
    }
}
