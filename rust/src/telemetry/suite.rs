//! The cheap telemetry suite behind `psram-imc bench-report`: reduced-size
//! versions of the headline, engine hot-loop, coordinator-scaling,
//! workload (sparse + Tucker), service-tier, and device-profile benches,
//! each emitting a [`BenchReport`] whose deterministic records are a pure
//! function of the code and the fixed PRNG seeds.
//!
//! Every area pairs *measured* cycle censuses (from actually executing
//! plans on the functional simulator) with the *predicted* envelope from
//! [`PerfModel::predict`] / [`PerfModel::predict_plan`] — the
//! sustained-vs-predicted artifact the paper (and the follow-on
//! system-level modeling work) treats as primary.  Wall-clock timings ride
//! along as [`MetricKind::WallClock`](super::MetricKind) records and never
//! gate.
//!
//! Workload sizes are deliberately small (the whole suite runs in seconds
//! in release mode — the CI job budget is minutes) but non-degenerate:
//! every area exercises multiple contraction blocks, rank blocks, and
//! partial lane batches, so the cycle censuses cover the same tiling
//! arithmetic the full benches do.

use super::{BenchEnv, BenchRecord, BenchReport, Direction};
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::energy::EnergyModel;
use crate::mttkrp::pipeline::{CpuTileExecutor, PsramPipeline, TileExecutor};
use crate::mttkrp::plan::{
    execute_plan, DensePlanner, SparseSlicePlanner, TilePlan, TtmPlanner,
};
use crate::mttkrp::MttkrpStats;
use crate::perfmodel::{headline, PerfModel, Workload};
use crate::session::{Engine, PsramSession};
use crate::tensor::{CooTensor, DenseTensor, Matrix};
use crate::tucker::{tucker_reconstruct, TuckerConfig, TuckerHooi};
use crate::util::error::{Error, Result};
use crate::util::prng::Prng;
use std::time::Instant;

/// The six bench areas, in baseline-file order.
pub const AREAS: [&str; 6] =
    ["headline", "engine", "coordinator", "workloads", "service", "device"];

/// Relative tolerance for ratio metrics (utilization, padding): exact up
/// to f64 formatting noise.
const TOL_RATIO: f64 = 1e-9;
/// Relative tolerance for model throughput/energy metrics: pure f64
/// arithmetic, allowed a hair of slack for cross-platform rounding.
const TOL_MODEL: f64 = 1e-6;
/// Relative tolerance for decomposition fits.  The Gaussian seed data
/// goes through platform `ln`/`sin_cos` (not correctly-rounded, so the
/// synthetic tensor itself shifts at f32 noise scale across hosts) and
/// the f32 HOOI pipeline on top; the gate is "the fit stays ~1", not a
/// bit pattern.
const TOL_FIT: f64 = 1e-3;

/// Baseline file name for an area: `BENCH_<area>.json`.
pub fn file_name(area: &str) -> String {
    format!("BENCH_{area}.json")
}

/// Run one area's cheap suite.  Unknown areas are an error (the CLI
/// surfaces [`AREAS`]).
pub fn run_area(area: &str, env: &BenchEnv) -> Result<BenchReport> {
    let mut report = BenchReport::new(area, env.clone());
    match area {
        "headline" => headline_area(&mut report)?,
        "engine" => engine_area(&mut report)?,
        "coordinator" => coordinator_area(&mut report)?,
        "workloads" => workloads_area(&mut report)?,
        "service" => service_area(&mut report)?,
        "device" => device_area(&mut report)?,
        other => {
            return Err(Error::telemetry(format!(
                "unknown bench area {other:?} (areas: {})",
                AREAS.join(", ")
            )))
        }
    }
    Ok(report)
}

/// Run every area (the default `bench-report` scope).
pub fn run_all(env: &BenchEnv) -> Result<Vec<BenchReport>> {
    AREAS.iter().map(|a| run_area(a, env)).collect()
}

/// Median wall seconds of `reps` runs of `f` (one unmeasured warmup).
fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f();
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn count(name: &str, v: u64, unit: &str) -> BenchRecord {
    BenchRecord::new(name, v as f64, unit)
}

fn ratio(name: &str, v: f64) -> BenchRecord {
    BenchRecord::new(name, v, "ratio").tol(TOL_RATIO)
}

fn wall(name: &str, secs: f64, n: u64) -> BenchRecord {
    BenchRecord::new(name, secs, "s")
        .better(Direction::Lower)
        .wall_clock()
        .samples(n)
}

/// §V.B headline: the model's 17.04-PetaOps peak + near-peak sustained
/// point, the predicted == measured cycle census on a reuse-heavy scaled
/// workload, and the analytic energy of the paper workload.
fn headline_area(report: &mut BenchReport) -> Result<()> {
    let (peak, sustained, util) = headline()?;
    report.push(
        BenchRecord::new("headline.peak_ops", peak, "ops/s")
            .better(Direction::Higher)
            .tol(TOL_MODEL),
    )?;
    report.push(
        BenchRecord::new("headline.sustained_ops", sustained, "ops/s")
            .better(Direction::Higher)
            .tol(TOL_MODEL),
    )?;
    report.push(ratio("headline.utilization", util))?;

    // Reuse-heavy scaled workload (40 lane batches, 2 contraction blocks,
    // rank 32): the functional pipeline's measured census must equal the
    // analytic model's prediction — the pin behind the paper's Fig. 5.
    let (i, k, r) = (2080usize, 512usize, 32usize);
    let mut rng = Prng::new(3);
    let unf = Matrix::randn(i, k, &mut rng);
    let krp = Matrix::randn(k, r, &mut rng);
    let mut exec = CpuTileExecutor::paper();
    let mut pipe = PsramPipeline::new(&mut exec);
    pipe.mttkrp_unfolded(&unf, &krp)?;
    let stats = pipe.stats;
    let est = PerfModel::paper().predict(&Workload {
        i_rows: i as u64,
        k_contraction: k as u64,
        rank: r as u64,
    })?;
    report.push(count("headline.scaled.measured_images", stats.images, "images"))?;
    report.push(count(
        "headline.scaled.measured_compute_cycles",
        stats.compute_cycles,
        "cycles",
    ))?;
    report.push(count(
        "headline.scaled.measured_write_cycles",
        stats.write_cycles,
        "cycles",
    ))?;
    report.push(count("headline.scaled.predicted_images", est.images, "images"))?;
    report.push(count(
        "headline.scaled.predicted_compute_cycles",
        est.compute_cycles,
        "cycles",
    ))?;
    report.push(count(
        "headline.scaled.predicted_write_cycles",
        est.write_cycles,
        "cycles",
    ))?;
    report.push(ratio("headline.scaled.measured_utilization", stats.utilization()))?;
    report.push(ratio("headline.scaled.predicted_utilization", est.utilization))?;

    // Analytic energy of the paper's 1M-per-mode workload (the simulator
    // cannot run it; the model predicts the ledger totals).
    let em = EnergyModel::paper();
    let paper_est = em.model.predict(&Workload::paper_large())?;
    let breakdown = em.predict(&paper_est);
    let useful_ops = 2.0 * Workload::paper_large().useful_macs();
    report.push(
        BenchRecord::new("headline.paper_energy_total_j", breakdown.total_j(), "J")
            .better(Direction::Lower)
            .tol(TOL_MODEL),
    )?;
    report.push(
        BenchRecord::new(
            "headline.paper_energy_per_op_j",
            breakdown.per_op_j(useful_ops),
            "J/op",
        )
        .better(Direction::Lower)
        .tol(TOL_MODEL),
    )?;

    // Simulator wall-clock (informational).
    let reps = 2;
    let t = time_median(reps, || {
        let mut e = CpuTileExecutor::paper();
        let mut p = PsramPipeline::new(&mut e);
        p.mttkrp_unfolded(&unf, &krp).unwrap();
    });
    report.push(wall("headline.scaled.mttkrp_wall_s", t, reps as u64))?;
    report.push(
        BenchRecord::new(
            "headline.scaled.simulated_mac_per_s",
            stats.useful_macs as f64 / t,
            "MAC/s",
        )
        .better(Direction::Higher)
        .wall_clock()
        .samples(reps as u64),
    )?;
    Ok(())
}

/// Push the measured-vs-predicted census of one executed plan under
/// `prefix.*` (the shared shape of the engine and workload areas).
fn push_plan_census(
    report: &mut BenchReport,
    prefix: &str,
    plan: &TilePlan,
    stats: &MttkrpStats,
) -> Result<()> {
    let est = PerfModel::paper().predict_plan(plan)?;
    for (metric, measured, predicted, unit) in [
        ("images", stats.images, est.images, "images"),
        ("compute_cycles", stats.compute_cycles, est.compute_cycles, "cycles"),
        ("write_cycles", stats.write_cycles, est.reconfig_write_cycles, "cycles"),
        ("useful_macs", stats.useful_macs, est.useful_macs, "MACs"),
        ("raw_macs", stats.raw_macs, est.raw_macs, "MACs"),
    ] {
        report.push(count(&format!("{prefix}.measured_{metric}"), measured, unit))?;
        report.push(count(&format!("{prefix}.predicted_{metric}"), predicted, unit))?;
    }
    report.push(ratio(&format!("{prefix}.measured_utilization"), stats.utilization()))?;
    report.push(ratio(&format!("{prefix}.predicted_utilization"), est.utilization))?;
    report.push(ratio(
        &format!("{prefix}.padding_efficiency"),
        stats.padding_efficiency(),
    ))?;
    report.push(
        BenchRecord::new(
            format!("{prefix}.predicted_sustained_ops"),
            est.sustained_raw_ops,
            "ops/s",
        )
        .better(Direction::Higher)
        .tol(TOL_MODEL),
    )?;
    Ok(())
}

/// The zero-allocation execution hot loop: one dense plan's steady-state
/// census plus its wall-clock simulated-MAC rate.
fn engine_area(report: &mut BenchReport) -> Result<()> {
    let mut rng = Prng::new(7);
    // 2 contraction blocks × 2 rank blocks = 4 images, 10 lane batches.
    let unf = Matrix::randn(520, 512, &mut rng);
    let krp = Matrix::randn(512, 64, &mut rng);
    let plan = DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp)?;
    let mut exec = CpuTileExecutor::paper();
    let mut stats = MttkrpStats::default();
    execute_plan(&mut exec, &plan, &mut stats)?;
    push_plan_census(report, "engine.dense", &plan, &stats)?;

    let reps = 3;
    let t = time_median(reps, || {
        let mut s = MttkrpStats::default();
        execute_plan(&mut exec, &plan, &mut s).unwrap();
    });
    report.push(wall("engine.dense.execute_wall_s", t, reps as u64))?;
    report.push(
        BenchRecord::new(
            "engine.dense.simulated_raw_mac_per_s",
            stats.raw_macs as f64 / t,
            "MAC/s",
        )
        .better(Direction::Higher)
        .wall_clock()
        .samples(reps as u64),
    )?;
    report.push(
        BenchRecord::new(
            "engine.dense.images_per_s",
            stats.images as f64 / t,
            "images/s",
        )
        .better(Direction::Higher)
        .wall_clock()
        .samples(reps as u64),
    )?;

    // Autotuned executor (geometry-driven chunking + intra-shard
    // striping): the census is bit-identical by contract — pinned by
    // tests/intra_parallel.rs — so only the wall-clock rate rides along.
    let tuned = crate::tune::auto_tune(
        exec.rows(),
        exec.words_per_row(),
        exec.max_lanes(),
        1,
    );
    let mut texec = CpuTileExecutor::paper().with_tuning(&tuned);
    let tt = time_median(reps, || {
        let mut s = MttkrpStats::default();
        execute_plan(&mut texec, &plan, &mut s).unwrap();
    });
    report.push(wall("engine.dense.tuned_execute_wall_s", tt, reps as u64))?;
    report.push(
        BenchRecord::new(
            "engine.dense.tuned_raw_mac_per_s",
            stats.raw_macs as f64 / tt,
            "MAC/s",
        )
        .better(Direction::Higher)
        .wall_clock()
        .samples(reps as u64),
    )?;

    // Direct kernel rate: the blocked i8×i8→i32 inner loop on one full
    // synthetic tile (m = lanes, k = rows, n = words-per-row).
    let (m, k, n) = (exec.max_lanes(), exec.rows(), exec.words_per_row());
    let mut krng = Prng::new(29);
    let codes: Vec<u8> = (0..m * k).map(|_| krng.next_u8()).collect();
    let image: Vec<i32> = (0..k * n).map(|_| krng.next_i8() as i32).collect();
    let mut out = vec![0i32; m * n];
    let kt = time_median(reps, || {
        crate::util::fixed::quant_matmul_i32_into(&codes, &image, m, k, n, &mut out);
    });
    report.push(
        BenchRecord::new(
            "engine.kernel.gmac_per_s",
            (m * k * n) as f64 / kt / 1e9,
            "GMAC/s",
        )
        .better(Direction::Higher)
        .wall_clock()
        .samples(reps as u64),
    )?;
    Ok(())
}

/// Coordinator scaling: one dense plan distributed over 1/2/4 shards —
/// the pool's measured cycle totals are scheduling-independent, so the
/// measured utilization must land exactly on `predict_plan`'s.
fn coordinator_area(report: &mut BenchReport) -> Result<()> {
    let mut rng = Prng::new(13);
    // 4 contraction blocks × 2 rank blocks = 8 images over 4 shard keys.
    let unf = Matrix::randn(520, 1024, &mut rng);
    let krp = Matrix::randn(1024, 64, &mut rng);
    let plan = DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp)?;

    for shards in [1usize, 2, 4] {
        let mut model = PerfModel::paper();
        model.num_arrays = shards;
        let est = model.predict_plan(&plan)?;
        let mut pool = Coordinator::spawn(CoordinatorConfig::new(shards), |_| {
            Ok(CpuTileExecutor::paper())
        })?;
        let t0 = Instant::now();
        pool.execute_plan(&plan)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let m = pool.metrics();
        let snap = m.snapshot();
        let get = |key: &str| {
            snap.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(0)
        };
        let p = format!("coordinator.shards{shards}");
        report.push(count(&format!("{p}.measured_images"), get("images"), "images"))?;
        report.push(count(
            &format!("{p}.measured_compute_cycles"),
            get("compute_cycles"),
            "cycles",
        ))?;
        report.push(count(
            &format!("{p}.measured_write_cycles"),
            get("write_cycles"),
            "cycles",
        ))?;
        report.push(ratio(&format!("{p}.measured_utilization"), m.utilization()))?;
        report.push(ratio(&format!("{p}.predicted_utilization"), est.utilization))?;
        report.push(count(
            &format!("{p}.predicted_bottleneck_cycles"),
            est.bottleneck_cycles,
            "cycles",
        ))?;
        report.push(
            BenchRecord::new(
                format!("{p}.predicted_sustained_ops"),
                est.sustained_raw_ops,
                "ops/s",
            )
            .better(Direction::Higher)
            .tol(TOL_MODEL),
        )?;
        report.push(wall(&format!("{p}.execute_wall_s"), wall_s, 1))?;
        report.push(
            BenchRecord::new(
                format!("{p}.images_per_s"),
                get("images") as f64 / wall_s,
                "images/s",
            )
            .better(Direction::Higher)
            .wall_clock()
            .samples(1),
        )?;
    }
    fault_recovery_records(report)
}

/// Fault-recovery determinism: a single-image plan on a one-worker
/// supervised pool, one request per fault class with pinned load indices
/// (worker 0's loads advance 0, 1, 2, … across requests, and the
/// respawned worker's counter restart cannot re-fire consumed events), so
/// every recovery counter below is an exact contract — not a statistic.
fn fault_recovery_records(report: &mut BenchReport) -> Result<()> {
    use crate::coordinator::RecoveryPolicy;
    use crate::fault::{
        silence_injected_death_panics, Backoff, DeathMode, FaultEvent, FaultInjector,
        FaultKind, FaultPlan, FaultPolicy, FaultyExecutor,
    };
    use std::sync::Arc;

    silence_injected_death_panics();
    let mut rng = Prng::new(19);
    // One contraction block × one rank block = exactly one stored image
    // (one batch) per request.
    let unf = Matrix::randn(20, 64, &mut rng);
    let krp = Matrix::randn(64, 8, &mut rng);
    let plan = DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp)?;
    let reference = {
        let mut exec = CpuTileExecutor::paper();
        let mut stats = MttkrpStats::default();
        execute_plan(&mut exec, &plan, &mut stats)?
    };

    // Request 1 loads 0 (transient → retry) and 1; request 2 load 2
    // (upset → scrub); request 3 load 3 (death → respawn, requeue; the
    // fresh executor re-loads at its own index 0, already consumed);
    // request 4 runs clean on the respawned worker.
    let events = vec![
        FaultEvent { worker: 0, load_idx: 0, kind: FaultKind::Transient },
        FaultEvent { worker: 0, load_idx: 2, kind: FaultKind::ImageUpset { bits: 3 } },
        FaultEvent { worker: 0, load_idx: 3, kind: FaultKind::WorkerDeath },
    ];
    let inj = Arc::new(FaultInjector::new(&FaultPlan::new(23, events)));
    let injector = Arc::clone(&inj);
    let mut cfg = CoordinatorConfig::new(1);
    cfg.recovery = RecoveryPolicy {
        backoff: Backoff::none(),
        ..RecoveryPolicy::default()
    };
    let mut pool = Coordinator::spawn(cfg, move |i| {
        Ok(FaultyExecutor::new(
            CpuTileExecutor::paper(),
            Arc::clone(&injector),
            i,
            DeathMode::Panic,
            &FaultPolicy::default(),
        ))
    })?;

    let t0 = Instant::now();
    let mut identical = 0u64;
    for _ in 0..4 {
        if pool.execute_plan(&plan)?.data() == reference.data() {
            identical += 1;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let (upsets, transients, deaths) = inj.injected();
    let m = pool.metrics();
    let snap = m.snapshot();
    let get = |key: &str| {
        snap.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(0)
    };
    let p = "coordinator.fault";
    report.push(count(&format!("{p}.injected_upsets"), upsets, "faults"))?;
    report.push(count(&format!("{p}.injected_transients"), transients, "faults"))?;
    report.push(count(&format!("{p}.injected_deaths"), deaths, "faults"))?;
    report.push(count(&format!("{p}.batch_retries"), get("batch_retries"), "batches"))?;
    report.push(count(
        &format!("{p}.requeued_batches"),
        get("requeued_batches"),
        "batches",
    ))?;
    report.push(count(
        &format!("{p}.worker_respawns"),
        get("worker_respawns"),
        "workers",
    ))?;
    report.push(count(&format!("{p}.scrubs"), get("scrubs"), "rewrites"))?;
    report.push(count(
        &format!("{p}.scrub_write_cycles"),
        get("scrub_write_cycles"),
        "cycles",
    ))?;
    report.push(count(
        &format!("{p}.bit_identical_requests"),
        identical,
        "requests",
    ))?;
    report.push(wall(&format!("{p}.recovery_wall_s"), wall_s, 1))?;
    Ok(())
}

/// The workload benches: sparse COO MTTKRP and the Tucker TTM census
/// (both predicted == measured through `predict_plan`), plus a small
/// end-to-end HOOI fit on the exact engine.
fn workloads_area(report: &mut BenchReport) -> Result<()> {
    let mut rng = Prng::new(17);

    // Sparse: 64×2048×16 at 1% density, rank 32 — slice plans grouped by
    // stored factor block.
    let shape = [64usize, 2048, 16];
    let nnz = (shape.iter().product::<usize>() as f64 * 0.01) as usize;
    let coo = CooTensor::random(&shape, nnz, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 32, &mut rng)).collect();
    let plan = SparseSlicePlanner::new(256, 32, 52).plan(&coo, &factors, 0)?;
    report.push(count("workloads.sparse.nnz", coo.nnz() as u64, "nnz"))?;
    let mut exec = CpuTileExecutor::paper();
    let mut stats = MttkrpStats::default();
    execute_plan(&mut exec, &plan, &mut stats)?;
    push_plan_census(report, "workloads.sparse", &plan, &stats)?;
    let reps = 2;
    let t = time_median(reps, || {
        let mut s = MttkrpStats::default();
        execute_plan(&mut exec, &plan, &mut s).unwrap();
    });
    report.push(wall("workloads.sparse.execute_wall_s", t, reps as u64))?;

    // Tucker TTM: X (512×52×20) ×₀ Uᵀ, rank 32 — 2 contraction blocks ×
    // 1 rank block, 20 lane batches of streamed tensor columns.
    let x = DenseTensor::randn(&[512, 52, 20], &mut rng);
    let u = Matrix::randn(512, 32, &mut rng);
    let ttm_plan = TtmPlanner::new(256, 32, 52).plan_ttm(&x, &u, 0)?;
    let mut ttm_stats = MttkrpStats::default();
    execute_plan(&mut exec, &ttm_plan, &mut ttm_stats)?;
    push_plan_census(report, "workloads.ttm", &ttm_plan, &ttm_stats)?;

    // End-to-end HOOI on the exact engine: a fixed-seed low-multilinear-
    // rank reconstruction target, so the ideal fit is exactly 1 and any
    // real run lands within f32 noise of it.  The sweep count is NOT a
    // deterministic contract: the early-stop compares successive fits,
    // and once the fit saturates, that difference is floating-point
    // noise — so `iters` rides along as an informational record.
    let ranks = vec![4usize, 4, 4];
    let core = DenseTensor::randn(&ranks, &mut rng);
    let truth: Vec<Matrix> = [24usize, 20, 16]
        .iter()
        .zip(&ranks)
        .map(|(&d, &r)| Matrix::randn(d, r, &mut rng))
        .collect();
    let x2 = tucker_reconstruct(&core, &truth)?;
    let hooi = TuckerHooi::new(TuckerConfig {
        ranks: ranks.clone(),
        max_iters: 4,
        tol: 1e-12,
    });
    let session = PsramSession::builder().engine(Engine::Exact).build()?;
    let res = hooi.run(&x2, &session)?;
    report.push(
        BenchRecord::new("workloads.hooi.iters", res.iters as f64, "sweeps")
            .wall_clock(),
    )?;
    report.push(
        BenchRecord::new("workloads.hooi.fit", res.final_fit(), "fit")
            .better(Direction::Higher)
            .tol(TOL_FIT),
    )?;
    Ok(())
}

/// Service tier: the hand-traced pinned admission scenario supplies the
/// committed, gating records (every figure in `BENCH_service.json` is
/// derivable by hand from the trace in
/// [`crate::service::traffic::pinned_report`] — counters, virtual-time
/// latency percentiles, per-tenant dispatch/busy accounting, and the
/// capacity-envelope utilization).  A seeded open-loop simulation and a
/// small live-scheduler run with per-tenant energy attribution ride
/// along; they are deterministic too, but intentionally not committed
/// yet — they fold into the baseline at the next `--write` re-baseline,
/// and until then they only ever classify as "added" (never gating).
fn service_area(report: &mut BenchReport) -> Result<()> {
    use crate::service::{
        pinned_report, JobSpec, PoolSpec, Scheduler, ServiceConfig, TenantId, TenantSpec,
        TrafficConfig,
    };

    // --- Pinned hand-traced scenario (the committed baseline). ---
    let pinned = pinned_report();
    let c = pinned.counters;
    for (name, v) in [
        ("submitted", c.submitted),
        ("admitted", c.admitted),
        ("rejected_full", c.rejected_full),
        ("rejected_quota", c.rejected_quota),
        ("cancelled", c.cancelled),
        ("dispatched", c.dispatched),
        ("completed", c.completed),
    ] {
        report.push(count(&format!("service.pinned.{name}"), v, "jobs"))?;
    }
    report.push(count("service.pinned.makespan_cycles", pinned.makespan, "cycles"))?;
    for (name, v) in [
        ("wait_p50_cycles", pinned.wait_p50),
        ("wait_p95_cycles", pinned.wait_p95),
        ("wait_p99_cycles", pinned.wait_p99),
        ("total_p50_cycles", pinned.total_p50),
        ("total_p95_cycles", pinned.total_p95),
        ("total_p99_cycles", pinned.total_p99),
    ] {
        report.push(
            BenchRecord::new(format!("service.pinned.{name}"), v, "cycles").tol(TOL_RATIO),
        )?;
    }
    for t in &pinned.per_tenant[..2] {
        report.push(count(
            &format!("service.pinned.tenant{}_dispatched", t.tenant.0),
            t.dispatched,
            "jobs",
        ))?;
        report.push(count(
            &format!("service.pinned.tenant{}_busy_cycles", t.tenant.0),
            t.busy_cycles,
            "cycles",
        ))?;
    }
    report.push(count("service.pinned.offered_cycles", pinned.offered_cycles, "cycles"))?;
    report.push(ratio("service.pinned.utilization", pinned.utilization))?;

    // --- Seeded open-loop simulation (deterministic, uncommitted). ---
    let model = PerfModel::paper();
    let mut cfg = TrafficConfig::paper(4242);
    for load in &mut cfg.tenants {
        load.jobs = 40;
    }
    let t0 = Instant::now();
    let seeded = cfg.run(&model)?;
    let sim_wall = t0.elapsed().as_secs_f64();
    report.push(count("service.seeded.admitted", seeded.counters.admitted, "jobs"))?;
    report.push(count("service.seeded.completed", seeded.counters.completed, "jobs"))?;
    report.push(count(
        "service.seeded.rejected_full",
        seeded.counters.rejected_full,
        "jobs",
    ))?;
    report.push(
        BenchRecord::new("service.seeded.wait_p95_cycles", seeded.wait_p95, "cycles")
            .tol(TOL_RATIO),
    )?;
    report.push(ratio("service.seeded.utilization", seeded.utilization))?;
    report.push(wall("service.seeded.sim_wall_s", sim_wall, 1))?;

    // --- Live scheduler smoke with per-tenant energy attribution
    //     (single pool + pause/resume keeps the dispatch order, and
    //     therefore the energy split, deterministic). ---
    let svc = ServiceConfig {
        queue_bound: 16,
        tenants: (0..3u32)
            .map(|i| (TenantId(i), TenantSpec { weight: 3 - i, quota: 8 }))
            .collect(),
        default_tenant: TenantSpec::default(),
    };
    let mut sched = Scheduler::new(&svc, &[PoolSpec::single()], PerfModel::paper())?;
    sched.pause();
    let mut handles = Vec::new();
    for i in 0..3u32 {
        for j in 0..2u64 {
            let spec = JobSpec::DenseMttkrp {
                shape: [32, 16, 8],
                rank: 4,
                mode: 0,
                seed: 100 + u64::from(i) * 10 + j,
            };
            handles.push(sched.submit(TenantId(i), spec).map_err(Error::from)?);
        }
    }
    let t1 = Instant::now();
    sched.resume();
    let done = handles.into_iter().map(|h| h.wait()).filter(|c| c.is_done()).count();
    let live_wall = t1.elapsed().as_secs_f64();
    report.push(count("service.live.completed", done as u64, "jobs"))?;
    for i in 0..3u32 {
        report.push(
            BenchRecord::new(
                format!("service.live.tenant{i}_energy_j"),
                sched.tenant_energy_j(TenantId(i)),
                "J",
            )
            .better(Direction::Lower)
            .tol(TOL_MODEL),
        )?;
    }
    sched.shutdown();
    report.push(wall("service.live.serve_wall_s", live_wall, 1))?;
    Ok(())
}

/// Device profiles: every registered profile's calibrated envelope —
/// predicted sustained throughput on the paper workload, analytic energy
/// per useful op, the detector-link SNR with its ADC-capped effective
/// bits — plus a measured-vs-predicted census of the X-pSRAM binary-op
/// (XOR) kernel.  Everything here is pure f64/integer arithmetic over
/// fixed seeds, so every record gates.
fn device_area(report: &mut BenchReport) -> Result<()> {
    use crate::compute::ComputeEngine;
    use crate::device::profiles;
    use crate::psram::PsramArray;

    let w = Workload::paper_large();
    for p in profiles::all() {
        let model = PerfModel::from_profile(&p);
        let est = model.predict(&w)?;
        let e = EnergyModel::from_profile(&p).predict(&est);
        let pre = format!("device.{}", p.name);
        report.push(
            BenchRecord::new(format!("{pre}.predicted_peak_ops"), est.peak_ops, "ops/s")
                .better(Direction::Higher)
                .tol(TOL_MODEL),
        )?;
        report.push(
            BenchRecord::new(
                format!("{pre}.predicted_sustained_ops"),
                est.sustained_raw_ops,
                "ops/s",
            )
            .better(Direction::Higher)
            .tol(TOL_MODEL),
        )?;
        report.push(ratio(&format!("{pre}.predicted_utilization"), est.utilization))?;
        report.push(
            BenchRecord::new(
                format!("{pre}.energy_per_op_j"),
                e.per_op_j(2.0 * w.useful_macs()),
                "J/op",
            )
            .better(Direction::Lower)
            .tol(TOL_MODEL),
        )?;
        report.push(
            BenchRecord::new(format!("{pre}.link_snr_db"), p.link_snr_db(), "dB")
                .better(Direction::Higher)
                .tol(TOL_MODEL),
        )?;
        report.push(
            BenchRecord::new(format!("{pre}.effective_bits"), p.effective_bits(), "bits")
                .better(Direction::Higher)
                .tol(TOL_MODEL),
        )?;
    }

    // X-pSRAM binary-op kernel: run a small batched XOR workload on the
    // functional simulator and pin its census against `predict_xor` — the
    // same measured == predicted contract the MAC areas enforce.
    let xp = profiles::x_psram_xor();
    let mut engine = ComputeEngine::from_profile(&xp);
    let mut array = PsramArray::paper();
    let mut rng = Prng::new(31);
    let img: Vec<i8> =
        (0..array.geometry().total_words()).map(|_| rng.next_i8()).collect();
    array.write_image(&img)?;
    let lane_counts = [52usize, 52, 17];
    let vectors: usize = lane_counts.iter().sum();
    let rows = array.geometry().rows;
    let bits: Vec<u8> = (0..vectors * rows).map(|_| rng.next_u8() & 1).collect();
    let mut out = vec![0u32; vectors * array.geometry().words_per_row()];
    engine.xor_block_into(&mut array, &bits, &lane_counts, &mut out)?;
    let est = PerfModel::from_profile(&xp).predict_xor(vectors as u64)?;
    report.push(count("device.xor.measured_cycles", engine.stats.xor_cycles, "cycles"))?;
    report.push(count("device.xor.predicted_cycles", est.xor_cycles, "cycles"))?;
    report.push(count("device.xor.measured_bit_ops", engine.stats.bit_ops, "bitops"))?;
    report.push(count("device.xor.predicted_bit_ops", est.bit_ops, "bitops"))?;
    report.push(count(
        "device.xor.hamming_checksum",
        out.iter().map(|&v| u64::from(v)).sum(),
        "bits",
    ))?;
    report.push(
        BenchRecord::new(
            "device.xor.predicted_sustained_bit_ops",
            est.sustained_bit_ops,
            "ops/s",
        )
        .better(Direction::Higher)
        .tol(TOL_MODEL),
    )?;
    report.push(
        BenchRecord::new(
            "device.xor.switching_energy_j",
            array.energy.switching_j,
            "J",
        )
        .better(Direction::Lower)
        .tol(TOL_MODEL),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::capture_env;

    #[test]
    fn unknown_area_rejected() {
        let env = capture_env(Some("2026-08-07"));
        assert!(run_area("nope", &env).is_err());
    }

    #[test]
    fn file_names_match_areas() {
        assert_eq!(file_name("headline"), "BENCH_headline.json");
        assert_eq!(file_name("service"), "BENCH_service.json");
        assert_eq!(file_name("device"), "BENCH_device.json");
        assert_eq!(AREAS.len(), 6);
    }

    #[test]
    fn device_area_xor_census_is_predicted_exact() {
        let env = capture_env(Some("2026-08-07"));
        let r = run_area("device", &env).unwrap();
        assert_eq!(
            r.value("device.xor.measured_cycles"),
            r.value("device.xor.predicted_cycles")
        );
        assert_eq!(
            r.value("device.xor.measured_bit_ops"),
            r.value("device.xor.predicted_bit_ops")
        );
        // The baseline profile reproduces the paper's headline peak.
        let base = r.value("device.baseline.predicted_peak_ops").unwrap();
        assert!((base / 1e15 - 17.04).abs() < 0.005);
        // A faster ADC front end must not predict slower sustained ops.
        let b = r.value("device.baseline.predicted_sustained_ops").unwrap();
        let eo = r.value("device.eo_adc.predicted_sustained_ops").unwrap();
        assert!(eo >= b, "eo_adc {eo} vs baseline {b}");
    }

    #[test]
    fn headline_area_census_is_predicted_exact() {
        let env = capture_env(Some("2026-08-07"));
        let r = run_area("headline", &env).unwrap();
        // the measured pipeline census equals the analytic model's
        for m in ["images", "compute_cycles", "write_cycles"] {
            assert_eq!(
                r.value(&format!("headline.scaled.measured_{m}")),
                r.value(&format!("headline.scaled.predicted_{m}")),
                "census metric {m}"
            );
        }
        // the paper pin: 17.04 PetaOps peak, sustained <= peak
        let peak = r.value("headline.peak_ops").unwrap();
        let sustained = r.value("headline.sustained_ops").unwrap();
        assert!((peak / 1e15 - 17.04).abs() < 0.005);
        assert!(sustained <= peak);
    }
}
