//! Cycle and energy accounting for a pSRAM array.
//!
//! The predictive performance model needs exact counts of compute vs
//! reconfiguration cycles (utilisation), and the energy report needs
//! switching/static/modulator/ADC/laser breakdowns.

/// Cycle counts by activity.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleLedger {
    /// Cycles spent computing (wordline activations with compute).
    pub compute: u64,
    /// Cycles spent writing/reconfiguring the array.
    pub write: u64,
    /// Idle cycles (stalls waiting for inputs/outputs).
    pub idle: u64,
}

impl CycleLedger {
    /// Total cycles elapsed.
    pub fn total(&self) -> u64 {
        self.compute + self.write + self.idle
    }

    /// Fraction of cycles doing useful compute (the model's utilisation U).
    pub fn utilization(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.compute as f64 / t as f64
        }
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &CycleLedger) {
        self.compute += other.compute;
        self.write += other.write;
        self.idle += other.idle;
    }

    /// Wall-clock time at a clock rate.
    pub fn seconds_at(&self, clock_hz: f64) -> f64 {
        self.total() as f64 / clock_hz
    }
}

/// Energy totals by source (J).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyLedger {
    /// Bitcell switching energy (writes that toggled a latch).
    pub switching_j: f64,
    /// Static/hold energy across all bitcells and cycles.
    pub static_j: f64,
    /// Comb-shaper modulation energy (input encoding).
    pub modulator_j: f64,
    /// ADC conversion energy.
    pub adc_j: f64,
    /// Laser/comb wall-plug energy attributed to the computation.
    pub laser_j: f64,
}

impl EnergyLedger {
    /// Total energy (J).
    pub fn total_j(&self) -> f64 {
        self.switching_j + self.static_j + self.modulator_j + self.adc_j + self.laser_j
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &EnergyLedger) {
        self.switching_j += other.switching_j;
        self.static_j += other.static_j;
        self.modulator_j += other.modulator_j;
        self.adc_j += other.adc_j;
        self.laser_j += other.laser_j;
    }

    /// Energy per operation given an op count.
    pub fn per_op_j(&self, ops: u64) -> f64 {
        if ops == 0 {
            0.0
        } else {
            self.total_j() / ops as f64
        }
    }

    /// Breakdown as (label, joules, fraction) rows for reports.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let t = self.total_j().max(1e-300);
        vec![
            ("switching", self.switching_j, self.switching_j / t),
            ("static", self.static_j, self.static_j / t),
            ("modulator", self.modulator_j, self.modulator_j / t),
            ("adc", self.adc_j, self.adc_j / t),
            ("laser", self.laser_j, self.laser_j / t),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_math() {
        let l = CycleLedger { compute: 80, write: 15, idle: 5 };
        assert_eq!(l.total(), 100);
        assert!((l.utilization() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn empty_ledger_utilization_zero() {
        assert_eq!(CycleLedger::default().utilization(), 0.0);
    }

    #[test]
    fn merge_adds_componentwise() {
        let mut a = CycleLedger { compute: 1, write: 2, idle: 3 };
        a.merge(&CycleLedger { compute: 10, write: 20, idle: 30 });
        assert_eq!(a, CycleLedger { compute: 11, write: 22, idle: 33 });
    }

    #[test]
    fn seconds_at_clock() {
        let l = CycleLedger { compute: 20_000_000_000, write: 0, idle: 0 };
        assert!((l.seconds_at(20e9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn energy_breakdown_fractions_sum_to_one() {
        let e = EnergyLedger {
            switching_j: 1e-9,
            static_j: 2e-9,
            modulator_j: 3e-9,
            adc_j: 4e-9,
            laser_j: 0.0,
        };
        let total: f64 = e.breakdown().iter().map(|(_, _, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!((e.total_j() - 1e-8).abs() < 1e-18);
    }

    #[test]
    fn per_op_energy() {
        let e = EnergyLedger { switching_j: 1e-6, ..Default::default() };
        assert!((e.per_op_j(1000) - 1e-9).abs() < 1e-18);
        assert_eq!(e.per_op_j(0), 0.0);
    }
}
