//! A pSRAM word: `word_bits` bitcells on one wordline storing one operand
//! in two's-complement bit-plane form.

use super::bitcell::Bitcell;
use crate::util::fixed::{plane_weight, WORD_BITS};

/// A group of bitcells holding one stored operand.
#[derive(Debug, Clone)]
pub struct Word {
    cells: Vec<Bitcell>,
}

impl Word {
    /// A cleared word of `bits` cells.
    pub fn new(bits: u32) -> Self {
        Word { cells: vec![Bitcell::default(); bits as usize] }
    }

    /// Number of bits.
    pub fn bits(&self) -> u32 {
        self.cells.len() as u32
    }

    /// Store an int8 value (two's complement across the bitcells).
    /// Returns the number of cells that toggled (for the energy ledger).
    pub fn store_i8(&mut self, value: i8) -> usize {
        assert_eq!(self.bits(), WORD_BITS, "store_i8 needs an 8-bit word");
        let pattern = value as u8;
        let mut flips = 0;
        for (b, cell) in self.cells.iter_mut().enumerate() {
            if cell.write((pattern >> b) & 1 == 1) {
                flips += 1;
            }
        }
        flips
    }

    /// Read back the stored int8 value.
    pub fn load_i8(&self) -> i8 {
        assert_eq!(self.bits(), WORD_BITS);
        let mut pattern = 0u8;
        for (b, cell) in self.cells.iter().enumerate() {
            if cell.read() {
                pattern |= 1 << b;
            }
        }
        pattern as i8
    }

    /// Bit `b` of the stored pattern.
    #[inline]
    pub fn bit(&self, b: u32) -> bool {
        self.cells[b as usize].read()
    }

    /// The optical multiply of an incoming intensity against the whole word:
    /// returns the per-plane gated intensities (what each bit-line carries
    /// before accumulation).  `out[b] = intensity * bit_b`.
    pub fn gate_planes(&self, intensity: u32) -> Vec<u32> {
        self.cells.iter().map(|c| c.gate(intensity)).collect()
    }

    /// Signed value of the product `intensity_signed * stored`, computed the
    /// way the optics + output encoding do: per-plane gate, then
    /// bit-significance weights.  Exactly equals `x * stored` for any x.
    pub fn optical_multiply(&self, x: i32) -> i64 {
        let stored: i64 = (0..self.bits())
            .map(|b| plane_weight(b) as i64 * self.bit(b) as i64)
            .sum();
        x as i64 * stored
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_load_roundtrip_full_range() {
        let mut w = Word::new(8);
        for v in i8::MIN..=i8::MAX {
            w.store_i8(v);
            assert_eq!(w.load_i8(), v);
        }
    }

    #[test]
    fn flip_count_is_hamming_distance() {
        let mut w = Word::new(8);
        assert_eq!(w.store_i8(0), 0); // from cleared
        assert_eq!(w.store_i8(0b0101_0101u8 as i8), 4);
        assert_eq!(w.store_i8(0b0101_0100u8 as i8), 1);
        assert_eq!(w.store_i8(0b0101_0100u8 as i8), 0);
    }

    #[test]
    fn gate_planes_reflect_bits() {
        let mut w = Word::new(8);
        w.store_i8(0b0000_0101);
        let planes = w.gate_planes(200);
        assert_eq!(planes[0], 200);
        assert_eq!(planes[1], 0);
        assert_eq!(planes[2], 200);
        assert!(planes[3..].iter().all(|&p| p == 0));
    }

    #[test]
    fn optical_multiply_equals_integer_multiply() {
        let mut w = Word::new(8);
        for &stored in &[-128i8, -77, -1, 0, 1, 42, 127] {
            w.store_i8(stored);
            for &x in &[-128i32, -3, 0, 5, 127] {
                assert_eq!(w.optical_multiply(x), x as i64 * stored as i64);
            }
        }
    }
}
