//! The photonic SRAM array: bitcells, words, the 2D crossbar, and the
//! cycle/energy ledgers (paper §III.B, §V.A).
//!
//! The paper's array is 256×256 bitcells; 8 bits along a row form a word,
//! giving 256 rows × 32 word columns.  Each bitcell is a cross-coupled
//! micro-ring latch writable at 20 GHz; reads (compute) are bounded by the
//! ring time constant.

pub mod array;
pub mod bitcell;
pub mod ledger;
pub mod word;

pub use array::PsramArray;
pub use bitcell::Bitcell;
pub use ledger::{CycleLedger, EnergyLedger};
pub use word::Word;

use crate::util::error::{Error, Result};

/// Geometry of one pSRAM array macro.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayGeometry {
    /// Word rows (wordlines).
    pub rows: usize,
    /// Bit columns.
    pub cols_bits: usize,
    /// Bits per word.
    pub word_bits: u32,
}

impl ArrayGeometry {
    /// The paper's configuration: 256×256 bits, 8-bit words -> 256×32 words.
    pub const PAPER: ArrayGeometry =
        ArrayGeometry { rows: 256, cols_bits: 256, word_bits: 8 };

    /// Construct and validate a geometry.
    pub fn new(rows: usize, cols_bits: usize, word_bits: u32) -> Result<Self> {
        let g = ArrayGeometry { rows, cols_bits, word_bits };
        g.validate()?;
        Ok(g)
    }

    /// Check internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols_bits == 0 {
            return Err(Error::config("geometry with zero extent"));
        }
        if self.word_bits == 0 || self.word_bits > 16 {
            return Err(Error::config(format!("word_bits={} unsupported", self.word_bits)));
        }
        if self.cols_bits % self.word_bits as usize != 0 {
            return Err(Error::config(format!(
                "cols_bits={} not a multiple of word_bits={}",
                self.cols_bits, self.word_bits
            )));
        }
        Ok(())
    }

    /// Word columns per row.
    pub fn words_per_row(&self) -> usize {
        self.cols_bits / self.word_bits as usize
    }

    /// Total words in the array.
    pub fn total_words(&self) -> usize {
        self.rows * self.words_per_row()
    }

    /// Total bitcells.
    pub fn total_bits(&self) -> usize {
        self.rows * self.cols_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_is_256x32_words() {
        let g = ArrayGeometry::PAPER;
        assert_eq!(g.words_per_row(), 32);
        assert_eq!(g.total_words(), 8192);
        assert_eq!(g.total_bits(), 65_536);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn ragged_geometry_rejected() {
        assert!(ArrayGeometry::new(256, 250, 8).is_err());
        assert!(ArrayGeometry::new(0, 256, 8).is_err());
        assert!(ArrayGeometry::new(256, 256, 0).is_err());
        assert!(ArrayGeometry::new(256, 256, 17).is_err());
    }

    #[test]
    fn alternate_geometries() {
        let g = ArrayGeometry::new(128, 512, 8).unwrap();
        assert_eq!(g.words_per_row(), 64);
        let g4 = ArrayGeometry::new(64, 64, 4).unwrap();
        assert_eq!(g4.words_per_row(), 16);
    }
}
