//! The pSRAM crossbar array: a 2D grid of words with write scheduling,
//! energy accounting and the packed view the compute engine reads.
//!
//! Writes happen one wordline per write cycle at the 20 GHz write clock
//! (the paper's "reconfigurability rate").  Compute reads are performed by
//! [`crate::compute::ComputeEngine`] against the packed mirror, which is
//! kept bit-identical to the bitcell grid (asserted in tests).

use super::bitcell::BitcellParams;
use super::ledger::{CycleLedger, EnergyLedger};
use super::word::Word;
use super::ArrayGeometry;
use crate::util::error::{Error, Result};

/// One pSRAM array macro.
#[derive(Debug, Clone)]
pub struct PsramArray {
    geom: ArrayGeometry,
    params: BitcellParams,
    /// Device-level state: `rows * words_per_row` words of bitcells.
    words: Vec<Word>,
    /// Packed mirror of the stored values, row-major `[rows][words_per_row]`,
    /// used by the compute hot path.
    packed: Vec<i8>,
    /// Sign-extended i32 mirror (perf: keeps the compute inner loop free of
    /// per-element i8->i32 extension; see EXPERIMENTS.md §Perf).
    packed_i32: Vec<i32>,
    /// Cached all-zero wordline for padded image writes (avoids a fresh
    /// `zeros` vector per `write_image_padded` call).
    zero_row: Vec<i8>,
    /// Cycle ledger for this array.
    pub cycles: CycleLedger,
    /// Energy ledger for this array.
    pub energy: EnergyLedger,
}

impl PsramArray {
    /// A cleared array with the paper's default bitcell parameters.
    pub fn new(geom: ArrayGeometry) -> Result<Self> {
        geom.validate()?;
        if geom.word_bits != 8 {
            return Err(Error::config(format!(
                "functional array currently models 8-bit words, got {}",
                geom.word_bits
            )));
        }
        let n = geom.total_words();
        Ok(PsramArray {
            geom,
            params: BitcellParams::default(),
            words: vec![Word::new(geom.word_bits); n],
            packed: vec![0i8; n],
            packed_i32: vec![0i32; n],
            zero_row: vec![0i8; geom.words_per_row()],
            cycles: CycleLedger::default(),
            energy: EnergyLedger::default(),
        })
    }

    /// The paper's 256×256-bit array.
    pub fn paper() -> Self {
        PsramArray::new(ArrayGeometry::PAPER).expect("paper geometry is valid")
    }

    /// Array geometry.
    pub fn geometry(&self) -> ArrayGeometry {
        self.geom
    }

    /// Bitcell parameters.
    pub fn params(&self) -> BitcellParams {
        self.params
    }

    /// Override the bitcell parameters (for ablations).
    pub fn set_params(&mut self, p: BitcellParams) {
        self.params = p;
    }

    /// Packed row-major stored values `[rows][words_per_row]`.
    #[inline]
    pub fn packed(&self) -> &[i8] {
        &self.packed
    }

    /// Sign-extended packed values (the compute hot path's view).
    #[inline]
    pub fn packed_i32(&self) -> &[i32] {
        &self.packed_i32
    }

    /// Stored value at `(row, col)`.
    pub fn word(&self, row: usize, col: usize) -> i8 {
        self.packed[row * self.geom.words_per_row() + col]
    }

    /// Write one full wordline (`words_per_row` values).  Costs one write
    /// cycle; switching energy is charged per toggled bitcell.
    pub fn write_row(&mut self, row: usize, values: &[i8]) -> Result<()> {
        let wpr = self.geom.words_per_row();
        if row >= self.geom.rows {
            return Err(Error::shape(format!("row {row} >= {}", self.geom.rows)));
        }
        if values.len() != wpr {
            return Err(Error::shape(format!(
                "row write needs {wpr} words, got {}",
                values.len()
            )));
        }
        let base = row * wpr;
        let mut flips = 0usize;
        for (c, &v) in values.iter().enumerate() {
            flips += self.words[base + c].store_i8(v);
            self.packed[base + c] = v;
            self.packed_i32[base + c] = v as i32;
        }
        self.cycles.write += 1;
        self.energy.switching_j += flips as f64 * self.params.switching_energy_j;
        Ok(())
    }

    /// Write a full array image, row-major `[rows][words_per_row]`.
    /// Costs `rows` write cycles — the reconfiguration stall the
    /// performance model charges between tiles.
    pub fn write_image(&mut self, image: &[i8]) -> Result<()> {
        let wpr = self.geom.words_per_row();
        if image.len() != self.geom.total_words() {
            return Err(Error::shape(format!(
                "image has {} words, array holds {}",
                image.len(),
                self.geom.total_words()
            )));
        }
        for row in 0..self.geom.rows {
            self.write_row(row, &image[row * wpr..(row + 1) * wpr])?;
        }
        Ok(())
    }

    /// Write a partial image of `rows_used` rows (remaining rows are zeroed
    /// so they do not contribute to column sums).
    pub fn write_image_padded(&mut self, image: &[i8], rows_used: usize) -> Result<()> {
        let wpr = self.geom.words_per_row();
        if rows_used > self.geom.rows {
            return Err(Error::shape(format!(
                "rows_used {rows_used} exceeds array rows {}",
                self.geom.rows
            )));
        }
        if image.len() != rows_used * wpr {
            return Err(Error::shape(format!(
                "partial image has {} words, want {}",
                image.len(),
                rows_used * wpr
            )));
        }
        for row in 0..rows_used {
            self.write_row(row, &image[row * wpr..(row + 1) * wpr])?;
        }
        // Reuse the cached zero wordline (taken out of `self` for the
        // duration of the writes, then restored — even on error).
        let zeros = std::mem::take(&mut self.zero_row);
        let mut result = Ok(());
        for row in rows_used..self.geom.rows {
            result = self.write_row(row, &zeros);
            if result.is_err() {
                break;
            }
        }
        self.zero_row = zeros;
        result
    }

    /// Charge static (hold) energy for `cycles` cycles across all bitcells.
    pub fn charge_static(&mut self, cycles: u64) {
        self.energy.static_j +=
            cycles as f64 * self.geom.total_bits() as f64 * self.params.static_energy_j;
    }

    /// Verify the packed mirror matches the bitcell grid (test/debug aid).
    pub fn check_mirror(&self) -> bool {
        self.words
            .iter()
            .zip(&self.packed)
            .zip(&self.packed_i32)
            .all(|((w, &p), &p32)| w.load_i8() == p && p as i32 == p32)
    }

    /// Reset ledgers (state is kept).
    pub fn reset_ledgers(&mut self) {
        self.cycles = CycleLedger::default();
        self.energy = EnergyLedger::default();
    }

    /// Inject stored-bit errors: each bitcell flips independently with
    /// probability `ber` (thermal-drift / retention fault model — see
    /// `device::mrr::MicroRing::thermal_ber`).  Returns the number of
    /// flipped bits.  The packed mirror stays consistent.
    pub fn inject_bit_errors(&mut self, ber: f64, rng: &mut crate::util::prng::Prng) -> usize {
        assert!((0.0..=1.0).contains(&ber));
        if ber == 0.0 {
            return 0;
        }
        let wpr = self.geom.words_per_row();
        let bits = self.geom.word_bits;
        let mut flips = 0usize;
        for w in 0..self.geom.total_words() {
            let mut val = self.packed[w] as u8;
            let mut changed = false;
            for b in 0..bits {
                if rng.uniform() < ber {
                    val ^= 1 << b;
                    changed = true;
                    flips += 1;
                }
            }
            if changed {
                let _ = wpr;
                self.words[w].store_i8(val as i8);
                self.packed[w] = val as i8;
                self.packed_i32[w] = val as i8 as i32;
            }
        }
        flips
    }

    /// Integrity scrub: compare the stored image against a `golden`
    /// row-major `[rows][words_per_row]` copy and rewrite only the rows
    /// that differ — each through [`PsramArray::write_row`], so every
    /// repaired row costs one charged write cycle plus per-toggled-bitcell
    /// switching energy.  Returns the number of rows rewritten: the
    /// targeted (and cheaper) counterpart of a full image reload after
    /// [`PsramArray::inject_bit_errors`] upsets.
    pub fn scrub_image(&mut self, golden: &[i8]) -> Result<usize> {
        let wpr = self.geom.words_per_row();
        let rows = self.geom.rows;
        if golden.len() != rows * wpr {
            return Err(Error::shape(format!(
                "scrub image needs {} words, got {}",
                rows * wpr,
                golden.len()
            )));
        }
        let mut rewritten = 0usize;
        for r in 0..rows {
            let base = r * wpr;
            if self.packed[base..base + wpr] != golden[base..base + wpr] {
                self.write_row(r, &golden[base..base + wpr])?;
                rewritten += 1;
            }
        }
        Ok(rewritten)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_image(rng: &mut Prng, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.next_i8()).collect()
    }

    #[test]
    fn scrub_rewrites_only_corrupted_rows_and_charges_them() {
        let mut a = PsramArray::paper();
        let mut rng = Prng::new(31);
        let img = rand_image(&mut rng, a.geometry().total_words());
        a.write_image(&img).unwrap();
        let clean_writes = a.cycles.write;
        // No corruption: a scrub is free.
        assert_eq!(a.scrub_image(&img).unwrap(), 0);
        assert_eq!(a.cycles.write, clean_writes);
        // Flip bits until at least one word changed, then scrub.
        let mut flips = 0;
        let mut ber_rng = Prng::new(32);
        while flips == 0 {
            flips = a.inject_bit_errors(1e-4, &mut ber_rng);
        }
        let dirty_rows = (0..a.geometry().rows)
            .filter(|&r| {
                let wpr = a.geometry().words_per_row();
                (0..wpr).any(|c| a.word(r, c) != img[r * wpr + c])
            })
            .count();
        assert!(dirty_rows > 0);
        let repaired = a.scrub_image(&img).unwrap();
        assert_eq!(repaired, dirty_rows, "exactly the corrupted rows rewrite");
        assert_eq!(a.cycles.write, clean_writes + dirty_rows as u64);
        assert_eq!(a.packed(), &img[..], "image restored bit-exactly");
        assert!(a.check_mirror());
        // Geometry mismatch is a typed error.
        assert!(a.scrub_image(&img[1..]).is_err());
    }

    #[test]
    fn write_image_roundtrip_and_mirror() {
        let mut a = PsramArray::paper();
        let mut rng = Prng::new(1);
        let img = rand_image(&mut rng, a.geometry().total_words());
        a.write_image(&img).unwrap();
        assert_eq!(a.packed(), &img[..]);
        assert!(a.check_mirror());
        assert_eq!(a.word(0, 0), img[0]);
        assert_eq!(a.word(255, 31), img[255 * 32 + 31]);
    }

    #[test]
    fn write_costs_one_cycle_per_row() {
        let mut a = PsramArray::paper();
        let img = vec![1i8; a.geometry().total_words()];
        a.write_image(&img).unwrap();
        assert_eq!(a.cycles.write, 256);
        assert_eq!(a.cycles.compute, 0);
    }

    #[test]
    fn switching_energy_charged_per_flip() {
        let mut a = PsramArray::paper();
        // all zeros -> no flips from the cleared state
        a.write_image(&vec![0i8; 8192]).unwrap();
        assert_eq!(a.energy.switching_j, 0.0);
        // -1 = 0xFF flips all 8 bits of every word
        a.write_image(&vec![-1i8; 8192]).unwrap();
        let expect = 8192.0 * 8.0 * a.params().switching_energy_j;
        assert!((a.energy.switching_j - expect).abs() < 1e-15);
    }

    #[test]
    fn rewriting_same_image_is_energy_free() {
        let mut a = PsramArray::paper();
        let mut rng = Prng::new(2);
        let img = rand_image(&mut rng, 8192);
        a.write_image(&img).unwrap();
        let e1 = a.energy.switching_j;
        a.write_image(&img).unwrap();
        assert_eq!(a.energy.switching_j, e1);
        // ... but still costs write cycles (the wordline must be driven)
        assert_eq!(a.cycles.write, 512);
    }

    #[test]
    fn padded_image_zeroes_tail_rows() {
        let mut a = PsramArray::paper();
        a.write_image(&vec![7i8; 8192]).unwrap();
        a.write_image_padded(&vec![3i8; 10 * 32], 10).unwrap();
        assert_eq!(a.word(5, 0), 3);
        assert_eq!(a.word(10, 0), 0);
        assert_eq!(a.word(255, 31), 0);
    }

    #[test]
    fn shape_errors() {
        let mut a = PsramArray::paper();
        assert!(a.write_image(&vec![0i8; 100]).is_err());
        assert!(a.write_row(256, &vec![0i8; 32]).is_err());
        assert!(a.write_row(0, &vec![0i8; 31]).is_err());
        assert!(a.write_image_padded(&vec![0i8; 32], 300).is_err());
    }

    #[test]
    fn static_energy_scales_with_cycles_and_bits() {
        let mut a = PsramArray::paper();
        a.charge_static(1000);
        let expect = 1000.0 * 65_536.0 * 16.7e-18;
        assert!((a.energy.static_j - expect).abs() < 1e-20);
    }

    #[test]
    fn bit_error_injection_flips_expected_fraction() {
        let mut a = PsramArray::paper();
        a.write_image(&vec![0i8; 8192]).unwrap();
        let mut rng = Prng::new(42);
        let flips = a.inject_bit_errors(0.01, &mut rng);
        let expect = 65_536.0 * 0.01;
        assert!((flips as f64 - expect).abs() < expect * 0.5, "flips={flips}");
        assert!(a.check_mirror());
        // zero BER is a no-op
        let before: Vec<i8> = a.packed().to_vec();
        assert_eq!(a.inject_bit_errors(0.0, &mut rng), 0);
        assert_eq!(a.packed(), &before[..]);
    }

    #[test]
    fn non_8bit_words_rejected_for_now() {
        assert!(PsramArray::new(ArrayGeometry::new(64, 64, 4).unwrap()).is_err());
    }
}
