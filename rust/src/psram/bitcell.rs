//! The pSRAM bitcell: a cross-coupled micro-ring latch (paper §III.B).
//!
//! Two MRRs (R1, R2) and two photodiodes (P1, P2): the through port of R1
//! drives P2 which controls R2's resonance, and vice versa — a differential
//! optical latch.  We model the *functional* state machine plus the paper's
//! energy/timing numbers: write at 20 GHz, ~1.04 pJ/bit switching energy,
//! ~16.7 aJ/bit static energy.

/// Energy/timing constants of the bitcell from the paper.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitcellParams {
    /// Energy to flip the latch (J/bit). Paper: ~1.04 pJ.
    pub switching_energy_j: f64,
    /// Static (hold) energy per bit per cycle (J). Paper: ~16.7 aJ.
    pub static_energy_j: f64,
    /// Maximum write (reconfiguration) rate (Hz). Paper: 20 GHz.
    pub max_write_rate_hz: f64,
}

impl Default for BitcellParams {
    fn default() -> Self {
        BitcellParams {
            switching_energy_j: 1.04e-12,
            static_energy_j: 16.7e-18,
            max_write_rate_hz: 20e9,
        }
    }
}

/// One cross-coupled MRR latch.
///
/// The differential pair stores `(q, !q)`; we track `q` and count state
/// flips so the array's energy ledger can charge switching energy only for
/// bits that actually toggled (writes of the same value are free at the
/// latch level, as in the physical device where the rings stay put).
#[derive(Debug, Clone, Default)]
pub struct Bitcell {
    q: bool,
}

impl Bitcell {
    /// Construct holding `value`.
    pub fn new(value: bool) -> Self {
        Bitcell { q: value }
    }

    /// Current stored bit.
    #[inline]
    pub fn read(&self) -> bool {
        self.q
    }

    /// Write a bit; returns `true` if the latch toggled (switching energy
    /// must be charged by the caller's ledger).
    #[inline]
    pub fn write(&mut self, value: bool) -> bool {
        let flipped = self.q != value;
        self.q = value;
        flipped
    }

    /// The differential outputs `(through_R1, through_R2)` of the latch:
    /// exactly one ring is on-resonance at a time.
    #[inline]
    pub fn differential(&self) -> (bool, bool) {
        (self.q, !self.q)
    }

    /// Optical multiply: the stored bit gates an incoming intensity code —
    /// the photonic product `input * bit` (paper Fig. 2: "Each pSRAM is
    /// capable of multiplying the values stored within the word by the
    /// inputs from the wavelengths").
    #[inline]
    pub fn gate(&self, intensity: u32) -> u32 {
        if self.q {
            intensity
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_read() {
        let mut c = Bitcell::default();
        assert!(!c.read());
        assert!(c.write(true));
        assert!(c.read());
    }

    #[test]
    fn rewrite_same_value_does_not_toggle() {
        let mut c = Bitcell::new(true);
        assert!(!c.write(true));
        assert!(c.write(false));
        assert!(!c.write(false));
    }

    #[test]
    fn differential_outputs_are_complementary() {
        let c = Bitcell::new(true);
        assert_eq!(c.differential(), (true, false));
        let c = Bitcell::new(false);
        assert_eq!(c.differential(), (false, true));
    }

    #[test]
    fn gate_multiplies_by_stored_bit() {
        let one = Bitcell::new(true);
        let zero = Bitcell::new(false);
        assert_eq!(one.gate(173), 173);
        assert_eq!(zero.gate(173), 0);
    }

    #[test]
    fn paper_energy_constants() {
        let p = BitcellParams::default();
        assert!((p.switching_energy_j - 1.04e-12).abs() < 1e-18);
        assert!((p.static_energy_j - 16.7e-18).abs() < 1e-24);
        assert_eq!(p.max_write_rate_hz, 20e9);
    }
}
