//! Analytic energy model + report generation.
//!
//! Two sources of truth exist and are cross-checked in tests:
//! * the *measured* ledgers the functional simulator accumulates
//!   ([`crate::psram::EnergyLedger`]), and
//! * this *analytic* model, which predicts the same totals from cycle
//!   counts — usable at scales the simulator cannot run (the 1M³ tensor).

use crate::device::DeviceParams;
use crate::perfmodel::{PerfEstimate, PerfModel};
use crate::psram::bitcell::BitcellParams;
use crate::util::units::format_energy;

/// Analytic energy model for one configuration.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Photonic component parameters (modulator/ADC/laser energy).
    pub device: DeviceParams,
    /// Bitcell energy numbers (switching + static).
    pub bitcell: BitcellParams,
    /// The performance model supplying cycle counts.
    pub model: PerfModel,
    /// Average fraction of bits that toggle on a word write (0.5 for
    /// random data — measured ledgers count exact flips).
    pub toggle_fraction: f64,
}

impl EnergyModel {
    /// Paper-default configuration.
    pub fn paper() -> Self {
        EnergyModel {
            device: DeviceParams::default(),
            bitcell: BitcellParams::default(),
            model: PerfModel::paper(),
            toggle_fraction: 0.5,
        }
    }

    /// Energy model calibrated from a validated device profile: the
    /// profile-lowered device parameters (modulator, per-profile ADC
    /// conversion energy, comb line power), the profile's bitcell energy
    /// numbers, and a [`PerfModel::from_profile`] cycle model.
    /// `from_profile(&baseline_psram())` equals [`EnergyModel::paper`]
    /// term for term — pinned in `tests/device_profiles.rs`.
    pub fn from_profile(p: &crate::device::DeviceProfile) -> Self {
        EnergyModel {
            device: p.device_params(),
            bitcell: p.bitcell_params(),
            model: PerfModel::from_profile(p),
            toggle_fraction: 0.5,
        }
    }

    /// Predict the energy of an MTTKRP execution described by a
    /// [`PerfEstimate`].
    pub fn predict(&self, est: &PerfEstimate) -> EnergyBreakdown {
        let geom = self.model.geom;
        let lanes = self.model.wavelengths as f64;
        let rows = geom.rows as f64;
        let wpr = geom.words_per_row() as f64;
        let bits = geom.total_bits() as f64;

        // Switching: every image rewrites all bits; toggle_fraction flip.
        let switching_j = est.images as f64
            * bits
            * self.toggle_fraction
            * self.bitcell.switching_energy_j;

        // Static: all bits, all cycles (compute + write), per array.
        let total_cycles = (est.compute_cycles + est.write_cycles) as f64;
        let static_j = total_cycles * bits * self.bitcell.static_energy_j
            * self.model.num_arrays as f64;

        // Modulators: lanes × rows symbols per compute cycle.
        let modulator_j = est.compute_cycles as f64
            * lanes
            * rows
            * self.device.shaper.energy_per_symbol_j
            * self.model.num_arrays as f64;

        // ADC: lanes × word-columns conversions per compute cycle.
        let adc_j = est.compute_cycles as f64
            * lanes
            * wpr
            * self.device.adc.energy_per_sample_j
            * self.model.num_arrays as f64;

        // Laser: per-line optical power for the whole runtime.
        let laser_j = self.device.comb.line_power_w
            * lanes
            * est.runtime_s
            * self.model.num_arrays as f64;

        EnergyBreakdown { switching_j, static_j, modulator_j, adc_j, laser_j }
    }
}

/// Predicted energy by source.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    /// Bitcell switching energy (J).
    pub switching_j: f64,
    /// Bitcell static energy (J).
    pub static_j: f64,
    /// Input modulator energy (J).
    pub modulator_j: f64,
    /// Readout ADC energy (J).
    pub adc_j: f64,
    /// Laser wall-plug energy (J).
    pub laser_j: f64,
}

impl EnergyBreakdown {
    /// Total (J).
    pub fn total_j(&self) -> f64 {
        self.switching_j + self.static_j + self.modulator_j + self.adc_j + self.laser_j
    }

    /// Energy per useful op (J/op).
    pub fn per_op_j(&self, useful_ops: f64) -> f64 {
        if useful_ops <= 0.0 {
            0.0
        } else {
            self.total_j() / useful_ops
        }
    }

    /// Formatted table rows: (label, energy string, percent).
    pub fn table(&self) -> Vec<(String, String, f64)> {
        let t = self.total_j().max(1e-300);
        [
            ("switching", self.switching_j),
            ("static", self.static_j),
            ("modulator", self.modulator_j),
            ("adc", self.adc_j),
            ("laser", self.laser_j),
        ]
        .iter()
        .map(|(n, j)| (n.to_string(), format_energy(*j), 100.0 * j / t))
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::Workload;

    #[test]
    fn paper_workload_energy_is_positive_and_dominated_sensibly() {
        let em = EnergyModel::paper();
        let est = em.model.predict(&Workload::paper_large()).unwrap();
        let e = em.predict(&est);
        assert!(e.total_j() > 0.0);
        // For a reuse-heavy workload, per-op energy should be deep
        // sub-picojoule — the whole point of in-memory photonics.
        let per_op = e.per_op_j(2.0 * Workload::paper_large().useful_macs());
        assert!(per_op < 1e-12, "per-op {per_op} J");
        assert!(per_op > 1e-18, "per-op {per_op} J suspiciously low");
    }

    #[test]
    fn more_reconfiguration_costs_more_switching() {
        let em = EnergyModel::paper();
        // same ops, less reuse (smaller I, more K blocks)
        let reuse_heavy = em
            .model
            .predict(&Workload { i_rows: 1_000_000, k_contraction: 25_600, rank: 32 })
            .unwrap();
        let reuse_light = em
            .model
            .predict(&Workload { i_rows: 52, k_contraction: 25_600 * 512, rank: 32 })
            .unwrap();
        let eh = em.predict(&reuse_heavy);
        let el = em.predict(&reuse_light);
        let frac_h = eh.switching_j / eh.total_j();
        let frac_l = el.switching_j / el.total_j();
        assert!(frac_l > frac_h, "switching fraction {frac_l} vs {frac_h}");
    }

    #[test]
    fn analytic_static_energy_matches_simulator_ledger() {
        // Run a small MTTKRP on the analog simulator and compare the static
        // energy against the analytic prediction for the same cycle counts.
        use crate::mttkrp::pipeline::{AnalogTileExecutor, PsramPipeline, TileExecutor};
        use crate::tensor::{DenseTensor, Matrix};
        use crate::util::prng::Prng;

        let mut rng = Prng::new(1);
        let x = DenseTensor::randn(&[60, 8, 8], &mut rng);
        let factors: Vec<Matrix> =
            [60, 8, 8].iter().map(|&d| Matrix::randn(d, 8, &mut rng)).collect();
        let mut exec = AnalogTileExecutor::ideal();
        let mut pipe = PsramPipeline::new(&mut exec);
        pipe.mttkrp(&x, &factors, 0).unwrap();
        let stats = pipe.stats;
        let measured = exec.energy().unwrap();

        // Analytic static energy: compute cycles only charge static in the
        // simulator (charge_static(1) per compute); writes don't.  Keep the
        // simulator honest about what it models:
        let bits = exec.array.geometry().total_bits() as f64;
        let analytic_static =
            stats.compute_cycles as f64 * bits * BitcellParams::default().static_energy_j;
        assert!(
            (measured.static_j - analytic_static).abs() <= 1e-12 * analytic_static.max(1.0),
            "measured {} vs analytic {}",
            measured.static_j,
            analytic_static
        );
    }

    #[test]
    fn table_percentages_sum_to_100() {
        let em = EnergyModel::paper();
        let est = em.model.predict(&Workload::paper_large()).unwrap();
        let e = em.predict(&est);
        let sum: f64 = e.table().iter().map(|(_, _, p)| p).sum();
        assert!((sum - 100.0).abs() < 1e-6);
    }
}
