//! Energy model of the pSRAM compute engine, built from the paper's device
//! numbers (§III.B: ~1.04 pJ/bit switching, ~16.7 aJ/bit static) plus the
//! modulator/ADC/laser contributions of the device stack.

pub mod report;

pub use report::{EnergyBreakdown, EnergyModel};
