//! The unified kernel-submission type: everything a [`super::PsramSession`]
//! can execute, in one enum.
//!
//! The paper treats the pSRAM array as one device that different tensor
//! kernels are *mapped onto*; [`Kernel`] is that mapping surface.  All
//! three variants lower to the same `PlanShape`/`PlanArena` tile-plan IR
//! (`crate::mttkrp::plan`), so the session plans once and dispatches every
//! kernel through the identical `execute_plan_into` contract — adding a
//! workload to the system means adding a `Kernel` variant, not a new
//! backend struct.
//!
//! A `Kernel` is a *borrowed description* (`Copy` — two or three
//! references and a slot index); the session never takes ownership of
//! operands.

use crate::mttkrp::reference::{dense_mttkrp, sparse_mttkrp};
use crate::tensor::{CooTensor, DenseTensor, Matrix};
use crate::tucker::backend::TtmStream;
use crate::util::error::Result;

/// One kernel submission: what to compute, against which operands.
///
/// ```
/// use psram_imc::session::{Kernel, PsramSession};
/// use psram_imc::tensor::{DenseTensor, Matrix};
/// use psram_imc::util::prng::Prng;
///
/// let mut rng = Prng::new(5);
/// let x = DenseTensor::randn(&[12, 10, 8], &mut rng);
/// let factors: Vec<Matrix> =
///     [12, 10, 8].iter().map(|&d| Matrix::randn(d, 4, &mut rng)).collect();
///
/// // The same submission surface serves every backend engine.
/// let session = PsramSession::builder().build().unwrap();
/// let m = session
///     .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 })
///     .unwrap();
/// assert_eq!((m.rows(), m.cols()), (12, 4));
/// ```
#[derive(Clone, Copy)]
pub enum Kernel<'a> {
    /// Dense MTTKRP along `mode`: `A ← X_(mode) · KRP(factors ≠ mode)`.
    /// Lowered by `DensePlanner`; the plan-cache slot is `mode`.
    DenseMttkrp {
        /// The decomposition target.
        x: &'a DenseTensor,
        /// Factor matrices, one per mode (`[shape[m], R]`).
        factors: &'a [Matrix],
        /// Output mode.
        mode: usize,
    },
    /// Sparse (COO) MTTKRP along `mode`, lowered slice-wise by
    /// `SparseSlicePlanner`; the plan-cache slot is `mode`.
    SparseMttkrp {
        /// The COO decomposition target.
        x: &'a CooTensor,
        /// Factor matrices, one per mode (`[shape[m], R]`).
        factors: &'a [Matrix],
        /// Output mode.
        mode: usize,
    },
    /// Dense TTM `Y_(mode)ᵀ = X_(mode)ᵀ @ u` (the Tucker/HOOI primitive),
    /// lowered by `TtmPlanner`.  `slot` is the caller-assigned chain
    /// position used as the plan-cache slot.  The cache tracks each
    /// slot's stream provenance (unfold mode, fixed vs changing), so
    /// switching mode or stream kind on a slot requantizes instead of
    /// serving stale streams — stable slots are a performance pattern,
    /// not a correctness contract.
    Ttm {
        /// The streamed operand (fixed decomposition target, or an
        /// intermediate chain matrix that changes every call).
        stream: TtmStream<'a>,
        /// The stored factor `[I_mode, R]`.
        u: &'a Matrix,
        /// Stable chain-position slot for plan caching.
        slot: usize,
    },
}

/// Which planner family a [`Kernel`] lowers through — one third of the
/// unified plan-cache key, so dense, sparse, and TTM plans of identical
/// tile geometry can never alias each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Dense MTTKRP plans (`DensePlanner`).
    DenseMttkrp,
    /// Sparse slice-wise MTTKRP plans (`SparseSlicePlanner`).
    SparseMttkrp,
    /// Tucker TTM plans (`TtmPlanner`), fixed- and changing-stream alike.
    Ttm,
}

impl Kernel<'_> {
    /// The planner family this kernel lowers through.
    pub fn kind(&self) -> KernelKind {
        match self {
            Kernel::DenseMttkrp { .. } => KernelKind::DenseMttkrp,
            Kernel::SparseMttkrp { .. } => KernelKind::SparseMttkrp,
            Kernel::Ttm { .. } => KernelKind::Ttm,
        }
    }

    /// The plan-cache slot within the kind's namespace: the mode for
    /// MTTKRP kernels, the chain slot for TTM kernels.
    pub fn slot(&self) -> usize {
        match self {
            Kernel::DenseMttkrp { mode, .. } => *mode,
            Kernel::SparseMttkrp { mode, .. } => *mode,
            Kernel::Ttm { slot, .. } => *slot,
        }
    }

    /// Label for logs and metrics rows.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::DenseMttkrp { .. } => "dense-mttkrp",
            Kernel::SparseMttkrp { .. } => "sparse-mttkrp",
            Kernel::Ttm { .. } => "ttm",
        }
    }

    /// Execute the kernel exactly on the CPU (f32, no quantization) — the
    /// `Engine::Exact` path and the reference every pSRAM engine is
    /// validated against.
    pub fn run_exact(&self) -> Result<Matrix> {
        match self {
            Kernel::DenseMttkrp { x, factors, mode } => {
                dense_mttkrp(x, factors, *mode)
            }
            Kernel::SparseMttkrp { x, factors, mode } => {
                sparse_mttkrp(x, factors, *mode)
            }
            Kernel::Ttm { stream, u, .. } => match stream {
                TtmStream::Fixed(x, mode) => {
                    x.unfold(*mode)?.transpose().matmul(u)
                }
                TtmStream::Changing(xt) => xt.matmul(u),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn kinds_slots_and_names() {
        let mut rng = Prng::new(1);
        let x = DenseTensor::randn(&[4, 4, 4], &mut rng);
        let coo = CooTensor::from_dense(&x, 0.0);
        let factors: Vec<Matrix> =
            [4, 4, 4].iter().map(|&d| Matrix::randn(d, 2, &mut rng)).collect();
        let u = Matrix::randn(4, 2, &mut rng);

        let d = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 1 };
        let s = Kernel::SparseMttkrp { x: &coo, factors: &factors, mode: 2 };
        let t = Kernel::Ttm { stream: TtmStream::Fixed(&x, 0), u: &u, slot: 5 };
        assert_eq!(d.kind(), KernelKind::DenseMttkrp);
        assert_eq!(s.kind(), KernelKind::SparseMttkrp);
        assert_eq!(t.kind(), KernelKind::Ttm);
        assert_eq!((d.slot(), s.slot(), t.slot()), (1, 2, 5));
        assert_eq!(d.name(), "dense-mttkrp");
        assert_eq!(s.name(), "sparse-mttkrp");
        assert_eq!(t.name(), "ttm");
    }

    #[test]
    fn run_exact_matches_references() {
        let mut rng = Prng::new(2);
        let x = DenseTensor::randn(&[6, 5, 4], &mut rng);
        let coo = CooTensor::from_dense(&x, 0.0);
        let factors: Vec<Matrix> =
            [6, 5, 4].iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
        let u = Matrix::randn(5, 3, &mut rng);

        let d = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 }
            .run_exact()
            .unwrap();
        let want = dense_mttkrp(&x, &factors, 0).unwrap();
        assert_eq!(d.data(), want.data());

        let s = Kernel::SparseMttkrp { x: &coo, factors: &factors, mode: 1 }
            .run_exact()
            .unwrap();
        let want = sparse_mttkrp(&coo, &factors, 1).unwrap();
        assert_eq!(s.data(), want.data());

        let t = Kernel::Ttm { stream: TtmStream::Fixed(&x, 1), u: &u, slot: 0 }
            .run_exact()
            .unwrap();
        let want = x.unfold(1).unwrap().transpose().matmul(&u).unwrap();
        assert_eq!(t.data(), want.data());

        let xt = x.unfold(1).unwrap().transpose();
        let t2 = Kernel::Ttm { stream: TtmStream::Changing(&xt), u: &u, slot: 1 }
            .run_exact()
            .unwrap();
        assert_eq!(t2.data(), t.data());
    }
}
