//! The unified `PsramSession` API: one kernel-submission surface over
//! every backend engine, with multi-tenant job sharing of the
//! coordinator pool.
//!
//! The paper's predictive model treats the pSRAM array as **one device**
//! that different kernels — dense/sparse MTTKRP, Tucker TTM — are mapped
//! onto.  This module makes the public API match that model:
//!
//! * [`SessionBuilder`] — device/array parameters (a
//!   [`PerfModel`]), an execution [`Engine`] (`Exact`, `SingleArray`, or
//!   `Coordinated { shards }`), a [`NoiseMode`], and a [`CachePolicy`];
//! * [`PsramSession`] — owns the executor or coordinator pool, the
//!   unified job-namespaced [`PlanCache`] (subsuming the three legacy
//!   per-kernel caches), and the `PerfModel`;
//! * [`Kernel`] — the one submission type; `session.run(kernel)` /
//!   `session.run_into(kernel, &mut out)` plan through the cache and
//!   dispatch every kernel through the identical `execute_plan_into`
//!   contract, so results are bit-identical to the legacy per-kernel
//!   backends (pinned in `tests/session_api.rs`);
//! * [`SessionJob`] — a cheap cloneable `(session, JobId)` handle:
//!   N concurrent decomposition jobs interleave on one warm coordinator
//!   pool, each with its own plan-cache namespace and its own cycle/energy
//!   attribution in [`Metrics`] ([`crate::coordinator::JobSnapshot`]);
//! * [`PsramSession::predict`] — scores the exact plan a submission
//!   executes through `PerfModel::predict_plan`, so
//!   **predicted == measured** holds per job (tested cycle-exactly);
//! * [`SessionBuilder::fault_policy`] — resilience: transient faults are
//!   retried with capped backoff (in place on the single array, at batch
//!   granularity inside the pool), checksum-detected stored-image upsets
//!   are scrubbed from the golden arena copy (charged, reported
//!   separately from the fault-free census), dead pool workers are
//!   respawned within a budget, and an exhausted recovery budget can
//!   reroute the submission to the exact digital engine — all surfaced
//!   in job metrics (retries, scrubs, re-queues, fallbacks).  A seeded
//!   [`SessionBuilder::fault_injector`] replays any fault schedule
//!   deterministically (`crate::fault`).
//!
//! Sessions are internally synchronized (`Send + Sync`): the plan cache
//! and the engine state live behind separate mutexes, and a submission
//! resolves its plan (an `Arc`-backed handle) and *releases* the cache
//! lock before executing — one tenant's running kernel never blocks
//! another tenant's planning or requantization.  Execution itself
//! time-shares the device: the single-array engine serializes at kernel
//! granularity, the coordinated engine at request granularity (the
//! leader runs one plan at a time; tenants' *requests* interleave FIFO
//! on the warm pool, their batches do not co-run).  What multi-tenancy
//! buys is one shared warm device with exact per-job attribution — the
//! "many jobs, one device" sharing the ROADMAP asks for.
//!
//! `CpAls` and `TuckerHooi` run on sessions ([`crate::cpd::CpAls::run`],
//! [`crate::tucker::TuckerHooi::run`]); the per-kernel backend structs in
//! `cpd::backend` / `tucker::backend` remain as the thin legacy layer the
//! session is pinned bit-identical against.

pub mod cache;
pub mod kernel;

pub use cache::{PlanCache, PlanKey};
pub use kernel::{Kernel, KernelKind};

use crate::compute::ComputeEngine;
use crate::coordinator::{
    Coordinator, CoordinatorConfig, JobSnapshot, Metrics, RecoveryPolicy,
};
use crate::device::{DeviceParams, NoiseModel};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::fault::{DeathMode, FaultInjector, FaultPolicy, FaultyExecutor};
use crate::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor, MttkrpStats, TileExecutor};
use crate::mttkrp::plan::{execute_plan_into, PlanScratch, TilePlan};
use crate::perfmodel::{PerfEstimate, PerfModel, PlanEstimate};
use crate::psram::{ArrayGeometry, EnergyLedger, PsramArray};
use crate::tensor::Matrix;
use crate::tune::TuneParams;
use crate::util::error::{Error, Result};
use std::sync::{Arc, Mutex, PoisonError};

/// Identifier of one tenant job on a session.  Jobs namespace the plan
/// cache (same-shape tensors of different jobs can never alias) and the
/// metrics attribution.  `JobId::DEFAULT` (0) is what the plain
/// [`PsramSession::run`] entry points use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct JobId(
    /// The raw job number — the namespace key in plan caches and metrics.
    pub u64,
);

impl JobId {
    /// The default job every plain `session.run` call is attributed to.
    pub const DEFAULT: JobId = JobId(0);
}

/// Which execution engine a session drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Exact f32 CPU references (no quantization, no device) — the
    /// baseline every pSRAM engine is validated against.
    Exact,
    /// One simulated pSRAM array (CPU integer twin by default; the
    /// device-faithful analog simulator with
    /// [`SessionBuilder::analog`] or any non-ideal [`NoiseMode`]).
    SingleArray,
    /// The sharded batched multi-array pool (`crate::coordinator`) with
    /// `shards` worker arrays — with noise off, bit-identical to
    /// `SingleArray` for every shard count and steal schedule, and
    /// shareable by many jobs.  (With noise on, each worker carries its
    /// own noise stream and work stealing makes batch placement
    /// timing-dependent, so noisy pooled results are statistically — not
    /// bitwise — reproducible.)
    Coordinated {
        /// Worker (array macro) count.
        shards: usize,
    },
}

/// Detector-noise configuration of the simulated arrays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NoiseMode {
    /// Bit-exact execution (ideal ADC, no detector noise).
    Ideal,
    /// Gaussian detector noise of `sigma_lsb` ADC LSBs; worker `i` of a
    /// pool derives its own deterministic stream from `seed` (the same
    /// `(seed ^ 0x77) + i` rule as the CLI).  Single-array noisy runs
    /// are bitwise reproducible; pooled noisy runs are not (work
    /// stealing makes the batch→worker→stream pairing timing-dependent)
    /// — only their noise *statistics* are pinned by the seed.
    Gaussian {
        /// Noise sigma in ADC LSBs.
        sigma_lsb: f64,
        /// Base seed of the per-worker noise streams.
        seed: u64,
    },
}

/// How a session tunes its digital (CPU) executors at build time.
///
/// Tuning never changes results or the deterministic cycle census — the
/// chunk size and worker width are bit-invisible by construction (see
/// [`crate::tune`]); it only changes host wall-clock.  Analog executors
/// are never tuned: they keep the fixed default chunk so their batched
/// f64 energy charges stay bit-stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunePolicy {
    /// Geometry-derived parameters refined by a one-shot microbenchmark
    /// ([`crate::tune::auto_tune`]), cached process-wide per geometry so
    /// repeated builds pay nothing.  The default.
    Auto,
    /// Explicit parameters (reproducible builds, tests, sweeps).
    Fixed(TuneParams),
}

/// Plan-cache policy of a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Cache plans per `(job, kernel kind, slot)` and requantize in place
    /// on reuse — ALS/HOOI iterations 2..N skip planning entirely.
    /// Bit-identical to `Disabled` (tested).
    Enabled,
    /// Plan every submission from scratch (debugging / memory-bound use).
    Disabled,
}

/// Builder for a [`PsramSession`].
///
/// ```
/// use psram_imc::session::{Engine, Kernel, NoiseMode, PsramSession};
/// use psram_imc::tensor::{DenseTensor, Matrix};
/// use psram_imc::util::prng::Prng;
///
/// // Device/array params come from the perf model; pick an engine.
/// let session = PsramSession::builder()
///     .engine(Engine::Coordinated { shards: 2 })
///     .build()
///     .unwrap();
///
/// let mut rng = Prng::new(9);
/// let x = DenseTensor::randn(&[10, 8, 6], &mut rng);
/// let factors: Vec<Matrix> =
///     [10, 8, 6].iter().map(|&d| Matrix::randn(d, 4, &mut rng)).collect();
/// let kernel = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
///
/// // predict() scores the exact plan run() executes: cycle-exact.
/// let predicted = session.predict(&kernel).unwrap();
/// session.run(kernel).unwrap();
/// let measured = session.job_metrics(Default::default());
/// assert_eq!(predicted.compute_cycles, measured.streamed_cycles);
/// assert_eq!(predicted.reconfig_write_cycles, measured.reconfig_write_cycles);
/// ```
pub struct SessionBuilder {
    model: PerfModel,
    engine: Engine,
    noise: NoiseMode,
    policy: CachePolicy,
    analog: bool,
    pool_config: Option<CoordinatorConfig>,
    executor: Option<Box<dyn TileExecutor + Send>>,
    tuning: TunePolicy,
    intra_workers: Option<usize>,
    fault: Option<FaultPolicy>,
    injector: Option<Arc<FaultInjector>>,
    /// Profile-lowered device parameters for the analog executors (and
    /// the admissibility checks); `None` keeps the paper defaults.
    device: Option<DeviceParams>,
    /// Profile bitcell energy numbers for the analog arrays' measured
    /// ledgers; `None` keeps the paper defaults.
    bitcell: Option<crate::psram::bitcell::BitcellParams>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            model: PerfModel::paper(),
            engine: Engine::SingleArray,
            noise: NoiseMode::Ideal,
            policy: CachePolicy::Enabled,
            analog: false,
            pool_config: None,
            executor: None,
            tuning: TunePolicy::Auto,
            intra_workers: None,
            fault: None,
            injector: None,
            device: None,
            bitcell: None,
        }
    }
}

impl SessionBuilder {
    /// A builder with the paper defaults: paper array model, single-array
    /// engine, CPU integer executor, no noise, plan caching on.
    pub fn new() -> Self {
        SessionBuilder::default()
    }

    /// Device/array parameters (geometry, wavelengths, clocks, array
    /// count for `predict`).  `num_arrays` is overwritten by the engine's
    /// actual array count on `build`.
    pub fn model(mut self, model: PerfModel) -> Self {
        self.model = model;
        self
    }

    /// The execution engine (default: [`Engine::SingleArray`]).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Build the session against a validated device profile
    /// ([`crate::device::DeviceProfile`]): the performance model is
    /// calibrated with [`PerfModel::from_profile`] (per-profile clocks,
    /// channel count, write overlap), the noise mode follows the
    /// profile's `NoiseSpec` (resolved for a full-column readout), and
    /// the analog executors are built from the profile-lowered
    /// [`DeviceParams`] and bitcell energy numbers instead of the module
    /// defaults.  `device_profile(&profiles::baseline_psram())` is
    /// bit-identical to the default builder — pinned in
    /// `tests/device_profiles.rs`.  Call before [`SessionBuilder::model`]
    /// or [`SessionBuilder::noise`] if you want to override parts of the
    /// profile afterwards.
    pub fn device_profile(mut self, profile: &crate::device::DeviceProfile) -> Self {
        self.model = PerfModel::from_profile(profile);
        self.noise = match profile.session_noise(ArrayGeometry::PAPER.rows) {
            None => NoiseMode::Ideal,
            Some((sigma_lsb, seed)) => NoiseMode::Gaussian { sigma_lsb, seed },
        };
        self.device = Some(profile.device_params());
        self.bitcell = Some(profile.bitcell_params());
        self
    }

    /// Detector-noise mode (default: [`NoiseMode::Ideal`]).  Any
    /// non-ideal mode implies the analog device simulator.
    pub fn noise(mut self, noise: NoiseMode) -> Self {
        self.noise = noise;
        self
    }

    /// Plan-cache policy (default: [`CachePolicy::Enabled`]).
    pub fn cache(mut self, policy: CachePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Use the device-faithful analog array simulator (cycle/energy
    /// ledgers, ADC path) instead of the fast CPU integer twin.  The two
    /// are bit-identical when noise is off; analog additionally meters
    /// energy ([`PsramSession::energy`]).
    pub fn analog(mut self, analog: bool) -> Self {
        self.analog = analog;
        self
    }

    /// Override the coordinated engine's pool shape (queue depth, batch
    /// size, stealing).  Its `workers` field wins over
    /// `Engine::Coordinated { shards }`.
    pub fn pool_config(mut self, cfg: CoordinatorConfig) -> Self {
        self.pool_config = Some(cfg);
        self
    }

    /// Provide a custom single-array executor (e.g. the PJRT runtime).
    /// Its tile geometry must match the model's; only valid with
    /// [`Engine::SingleArray`].
    pub fn executor(mut self, exec: Box<dyn TileExecutor + Send>) -> Self {
        self.executor = Some(exec);
        self
    }

    /// Tuning policy for the digital executors (default
    /// [`TunePolicy::Auto`]).  Bit-invisible: tuning only changes host
    /// wall-clock, never results or the deterministic census.
    pub fn tuning(mut self, policy: TunePolicy) -> Self {
        self.tuning = policy;
        self
    }

    /// Override the intra-shard worker width (1 = sequential execution;
    /// `n >= 2` stripes each compute block over `n` host threads per
    /// array).  Wins over the tuning policy's pick.
    pub fn intra_workers(mut self, width: usize) -> Self {
        self.intra_workers = Some(width.max(1));
        self
    }

    /// Fault-handling policy of the session (default
    /// [`FaultPolicy::default`]: retry transient faults with backoff,
    /// scrub detected image upsets, no digital fallback).  On the
    /// coordinated engine the policy also shapes the pool's
    /// [`RecoveryPolicy`] (batch retries, backoff, worker respawn
    /// budget), overriding any [`SessionBuilder::pool_config`] recovery
    /// settings.  With [`FaultPolicy::fallback`] set, a submission whose
    /// recovery budget is exhausted reroutes to the exact digital engine
    /// ([`Kernel::run_exact`]) instead of erroring — counted in
    /// [`crate::coordinator::JobSnapshot::fallbacks`].
    pub fn fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault = Some(policy);
        self
    }

    /// Install a deterministic fault injector: every simulated-array
    /// executor the session builds is wrapped in a
    /// [`FaultyExecutor`] drawing from this shared schedule (chaos
    /// testing; replayable from the plan's seed).  Production sessions
    /// leave this unset — the recovery machinery then only reacts to
    /// faults the executors raise on their own.
    pub fn fault_injector(mut self, injector: Arc<FaultInjector>) -> Self {
        self.injector = Some(injector);
        self
    }

    /// The owned per-worker executor factory for this configuration.
    /// Owned (no borrows of the builder) because the coordinator retains
    /// it for the lifetime of the pool to respawn dead workers.
    fn executor_factory(&self, tuned: TuneParams, death: DeathMode) -> ExecutorFactory {
        ExecutorFactory {
            analog: self.analog || !matches!(self.noise, NoiseMode::Ideal),
            noise: self.noise,
            rows: self.model.geom.rows,
            wpr: self.model.geom.words_per_row(),
            lanes: self.model.wavelengths,
            tuned,
            injector: self.injector.clone(),
            fault: self.fault.unwrap_or_default(),
            death,
            params: self.device.clone().unwrap_or_default(),
            bitcell: self.bitcell.unwrap_or_default(),
        }
    }

    /// Build the session: validate the model, construct the engine state
    /// (spawning the pool for [`Engine::Coordinated`]), and size the
    /// unified plan cache to the array geometry.
    pub fn build(self) -> Result<PsramSession> {
        let mut model = self.model.clone();
        model.validate()?;
        let analog = self.analog || !matches!(self.noise, NoiseMode::Ideal);
        if analog {
            // The analog simulator is the paper device: its array and
            // comb are fixed, so the model must describe that hardware.
            if model.geom != ArrayGeometry::PAPER {
                return Err(Error::config(format!(
                    "analog engine simulates the paper {}x{} array; custom \
                     geometries need the CPU executor",
                    ArrayGeometry::PAPER.rows,
                    ArrayGeometry::PAPER.cols_bits
                )));
            }
            let comb = self
                .device
                .as_ref()
                .map_or_else(|| DeviceParams::default().comb.max_channels(), |d| {
                    d.comb.max_channels()
                });
            if model.wavelengths > comb {
                return Err(Error::config(format!(
                    "{} wavelengths exceed the analog comb's {comb} channels",
                    model.wavelengths
                )));
            }
        }
        if self.executor.is_some() && !matches!(self.engine, Engine::SingleArray) {
            return Err(Error::config(
                "a custom executor requires Engine::SingleArray".to_string(),
            ));
        }

        let rows = model.geom.rows;
        let wpr = model.geom.words_per_row();
        let lanes = model.wavelengths;

        // Resolve the tuned execution parameters once per build, before
        // any executor is constructed.  Only digital (CPU) executors
        // consume them, so sessions that build none — exact engine,
        // analog simulator, custom executor — skip the microbenchmark.
        let arrays = match self.engine {
            Engine::Coordinated { shards } => {
                self.pool_config.as_ref().map_or(shards, |c| c.workers).max(1)
            }
            _ => 1,
        };
        let builds_cpu = !analog
            && match self.engine {
                Engine::Exact => false,
                Engine::SingleArray => self.executor.is_none(),
                Engine::Coordinated { .. } => true,
            };
        let mut tuned = if builds_cpu {
            match self.tuning {
                TunePolicy::Auto => crate::tune::auto_tune(rows, wpr, lanes, arrays),
                TunePolicy::Fixed(p) => p,
            }
        } else {
            TuneParams::default()
        };
        if let Some(width) = self.intra_workers {
            tuned.intra_workers = width;
        }

        let fault = self.fault.unwrap_or_default();
        let state = match self.engine {
            Engine::Exact => {
                model.num_arrays = 1;
                EngineState::Exact
            }
            Engine::SingleArray => {
                model.num_arrays = 1;
                // Single-array injected deaths surface as typed errors:
                // there is no supervisor thread to catch a panic here, and
                // the session's retry/fallback path handles `Error::Fault`.
                let factory = self.executor_factory(tuned, DeathMode::Error);
                let exec = match self.executor {
                    Some(exec) => {
                        if exec.rows() != rows
                            || exec.words_per_row() != wpr
                            || exec.max_lanes() < lanes
                        {
                            return Err(Error::config(format!(
                                "custom executor is {}x{} words x {} lanes but \
                                 the model needs {rows}x{wpr} x {lanes}",
                                exec.rows(),
                                exec.words_per_row(),
                                exec.max_lanes()
                            )));
                        }
                        factory.wrap(exec, 0)
                    }
                    None => factory.make(0),
                };
                EngineState::Single {
                    metrics: Arc::new(Metrics::with_shards(1)),
                    state: Mutex::new(SingleState {
                        exec,
                        scratch: PlanScratch::default(),
                    }),
                }
            }
            Engine::Coordinated { shards } => {
                let mut cfg = self
                    .pool_config
                    .clone()
                    .unwrap_or_else(|| CoordinatorConfig::new(shards));
                if let Some(fp) = self.fault {
                    // An explicit fault policy shapes the pool's recovery
                    // machinery too (documented on `fault_policy`).
                    cfg.recovery = RecoveryPolicy {
                        max_batch_retries: fp.retries,
                        backoff: fp.backoff,
                        respawn_budget: fp.respawn_budget,
                    };
                }
                model.num_arrays = cfg.workers.max(1);
                // Pool workers die by panic so the supervisor observes the
                // death, re-queues the batch, and respawns from this
                // factory (which the coordinator keeps, hence owned).
                let factory = self.executor_factory(tuned, DeathMode::Panic);
                let pool = Coordinator::spawn(cfg, move |i| Ok(factory.make(i)))?;
                EngineState::Pool { metrics: pool.metrics_handle(), pool: Mutex::new(pool) }
            }
        };

        Ok(PsramSession {
            core: Arc::new(SessionCore {
                model,
                engine: self.engine,
                policy: self.policy,
                fault,
                cache: Mutex::new(PlanCache::new(rows, wpr, lanes)),
                exact_metrics: Arc::new(Metrics::default()),
                state,
            }),
        })
    }
}

/// Owned per-worker executor factory: everything a session needs to build
/// (or *re*build, after a supervised worker death) one simulated-array
/// executor, captured by value.  The coordinator retains it for the pool's
/// lifetime, so it must not borrow the builder.
struct ExecutorFactory {
    analog: bool,
    noise: NoiseMode,
    rows: usize,
    wpr: usize,
    lanes: usize,
    tuned: TuneParams,
    injector: Option<Arc<FaultInjector>>,
    fault: FaultPolicy,
    death: DeathMode,
    /// Device parameters for the analog engines (profile-lowered when the
    /// session was built through [`SessionBuilder::device_profile`]).
    params: DeviceParams,
    /// Bitcell energy numbers for the analog arrays' measured ledgers.
    bitcell: crate::psram::bitcell::BitcellParams,
}

impl ExecutorFactory {
    /// Build worker `i`'s executor.  Digital executors get the resolved
    /// tuning; analog executors are never tuned (their batched f64 energy
    /// charges must stay chunk-stable).
    fn make(&self, worker: usize) -> Box<dyn TileExecutor + Send> {
        let inner: Box<dyn TileExecutor + Send> = if self.analog {
            let engine = match self.noise {
                NoiseMode::Ideal => {
                    ComputeEngine::new(self.params.clone(), NoiseModel::Off)
                }
                NoiseMode::Gaussian { sigma_lsb, seed } => ComputeEngine::new(
                    self.params.clone(),
                    NoiseModel::gaussian(
                        sigma_lsb,
                        (seed ^ 0x77).wrapping_add(worker as u64),
                    ),
                ),
            };
            let mut array = PsramArray::paper();
            array.set_params(self.bitcell);
            Box::new(AnalogTileExecutor::new(engine, array))
        } else {
            Box::new(
                CpuTileExecutor::new(self.rows, self.wpr, self.lanes)
                    .with_tuning(&self.tuned),
            )
        };
        self.wrap(inner, worker)
    }

    /// Wrap an executor in the session's [`FaultyExecutor`] when a fault
    /// injector is installed; a no-op pass-through otherwise.
    fn wrap(
        &self,
        inner: Box<dyn TileExecutor + Send>,
        worker: usize,
    ) -> Box<dyn TileExecutor + Send> {
        match &self.injector {
            Some(inj) => Box::new(FaultyExecutor::new(
                inner,
                Arc::clone(inj),
                worker,
                self.death,
                &self.fault,
            )),
            None => inner,
        }
    }
}

/// Single-array engine state: the executor plus its reusable scratch.
struct SingleState {
    exec: Box<dyn TileExecutor + Send>,
    scratch: PlanScratch,
}

/// The engine behind a session.  Metrics handles live *outside* the
/// engine mutexes (the counters are atomics), so metric reads never
/// block on a running kernel.
enum EngineState {
    /// Exact CPU references (no device state).
    Exact,
    /// One simulated array behind a mutex (kernel-granularity sharing;
    /// same counter layout as the coordinator, so `session.metrics()`
    /// reads uniformly across engines).
    Single {
        metrics: Arc<Metrics>,
        state: Mutex<SingleState>,
    },
    /// The coordinator pool behind a mutex (request-granularity sharing).
    Pool {
        metrics: Arc<Metrics>,
        pool: Mutex<Coordinator>,
    },
}

/// Shared state of a session; `PsramSession` and every [`SessionJob`] are
/// `Arc` handles onto one of these.
struct SessionCore {
    model: PerfModel,
    engine: Engine,
    policy: CachePolicy,
    /// Fault-handling policy every submission runs under (retry budget,
    /// backoff, digital fallback).
    fault: FaultPolicy,
    /// The unified plan store.  Submissions lock it only to resolve a
    /// plan (an `Arc`-backed clone) and release it before taking the
    /// engine lock — the two are never held together.
    cache: Mutex<PlanCache>,
    /// Request counters for the exact engine (no cycles to meter).
    exact_metrics: Arc<Metrics>,
    state: EngineState,
}

impl SessionCore {
    fn metrics(&self) -> Arc<Metrics> {
        match &self.state {
            EngineState::Exact => Arc::clone(&self.exact_metrics),
            EngineState::Single { metrics, .. } => Arc::clone(metrics),
            EngineState::Pool { metrics, .. } => Arc::clone(metrics),
        }
    }

    /// Lock the plan cache, recovering from poisoning rather than
    /// propagating another tenant's panic: the cache's critical sections
    /// are map lookups/inserts of `Arc`-backed plans, so the store stays
    /// structurally valid even if a panic mid-planning poisoned the lock.
    fn lock_cache(&self) -> std::sync::MutexGuard<'_, PlanCache> {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// The unified session handle — see the [module docs](self) for the full
/// architecture.
///
/// ```
/// use psram_imc::session::{Kernel, PsramSession};
/// use psram_imc::tensor::{DenseTensor, Matrix};
/// use psram_imc::util::prng::Prng;
///
/// let mut rng = Prng::new(3);
/// let x = DenseTensor::randn(&[14, 9, 7], &mut rng);
/// let factors: Vec<Matrix> =
///     [14, 9, 7].iter().map(|&d| Matrix::randn(d, 5, &mut rng)).collect();
///
/// // Default session: one simulated array, plan caching on.
/// let session = PsramSession::builder().build().unwrap();
/// let a = session
///     .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 1 })
///     .unwrap();
/// assert_eq!((a.rows(), a.cols()), (9, 5));
///
/// // run_into reuses a caller buffer on the zero-allocation hot path.
/// let mut out = Matrix::zeros(9, 5);
/// session
///     .run_into(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 1 }, &mut out)
///     .unwrap();
/// assert_eq!(out.data(), a.data());
/// ```
#[derive(Clone)]
pub struct PsramSession {
    core: Arc<SessionCore>,
}

impl PsramSession {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// A handle submitting under job `id`: cheap, cloneable, `Send` —
    /// hand one to each concurrent decomposition job sharing this
    /// session's device.
    pub fn job(&self, id: JobId) -> SessionJob {
        SessionJob { core: Arc::clone(&self.core), id }
    }

    /// Run a kernel under the default job and return the result matrix.
    pub fn run(&self, kernel: Kernel<'_>) -> Result<Matrix> {
        self.job(JobId::DEFAULT).run(kernel)
    }

    /// Run a kernel under the default job into a caller-provided output
    /// (must match the kernel's result dimensions; zeroed here).
    pub fn run_into(&self, kernel: Kernel<'_>, out: &mut Matrix) -> Result<()> {
        self.job(JobId::DEFAULT).run_into(kernel, out)
    }

    /// Score the exact plan `run` would execute for this kernel (default
    /// job): predicted images, streamed cycles, reconfiguration writes,
    /// lane occupancy, sustained throughput.  On the pSRAM engines this
    /// is cycle-exact against the measured metrics of the matching `run`
    /// (tested); on [`Engine::Exact`] it is the device model's forecast
    /// (the exact engine executes no array cycles).
    pub fn predict(&self, kernel: &Kernel<'_>) -> Result<PlanEstimate> {
        self.job(JobId::DEFAULT).predict(kernel)
    }

    /// The engine this session was built with.
    pub fn engine(&self) -> Engine {
        self.core.engine
    }

    /// The device/array model (with `num_arrays` reflecting the engine).
    pub fn model(&self) -> &PerfModel {
        &self.core.model
    }

    /// The session's metrics: global, per-shard, and per-job counters
    /// (atomics — reading never blocks submissions).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.core.metrics()
    }

    /// Point-in-time counters of one job (all-zero before its first
    /// submission).
    pub fn job_metrics(&self, id: JobId) -> JobSnapshot {
        self.core.metrics().job_snapshot(id.0)
    }

    /// Analytic energy attribution of one job: the job's measured cycle
    /// split run through the paper's [`EnergyModel`] (single-array-
    /// equivalent accounting — per-job cycles are summed across shards).
    pub fn job_energy(&self, id: JobId) -> EnergyBreakdown {
        let snap = self.job_metrics(id);
        let mut em = EnergyModel::paper();
        em.model = self.core.model.clone();
        em.model.num_arrays = 1;
        let padding = if snap.raw_macs == 0 {
            0.0
        } else {
            snap.useful_macs as f64 / snap.raw_macs as f64
        };
        let peak = em.model.peak_ops();
        let est = PerfEstimate {
            peak_ops: peak,
            sustained_raw_ops: peak * snap.utilization(),
            sustained_useful_ops: peak * snap.utilization() * padding,
            utilization: snap.utilization(),
            padding_efficiency: padding,
            images: snap.images,
            compute_cycles: snap.streamed_cycles,
            write_cycles: snap.reconfig_write_cycles,
            runtime_s: snap.total_cycles() as f64 / em.model.clock_hz,
        };
        em.predict(&est)
    }

    /// The measured energy ledger of a single-array analog engine
    /// (`None` for exact/CPU/pool engines, which meter analytically).
    pub fn energy(&self) -> Option<EnergyLedger> {
        match &self.core.state {
            // A poisoned executor lock (prior kernel panic) reads as "no
            // meaningful ledger" rather than a second panic.
            EngineState::Single { state, .. } => match state.lock() {
                Ok(st) => st.exec.energy(),
                Err(_) => None,
            },
            _ => None,
        }
    }

    /// Number of plans currently cached across all jobs.
    pub fn cached_plans(&self) -> usize {
        self.core.lock_cache().len()
    }

    /// Drop every cached plan (all jobs).
    pub fn clear_cache(&self) {
        self.core.lock_cache().clear();
    }

    /// Drop one job's cached plans, leaving other tenants warm — required
    /// before recycling a [`JobId`] for a different same-shape tensor.
    pub fn clear_job(&self, id: JobId) {
        self.core.lock_cache().clear_job(id.0);
    }

    /// Gracefully shut down a pooled engine: drain queued batches, join
    /// every worker thread.  No-op on the exact and single-array engines
    /// (they own no threads).
    ///
    /// Safe to call from any clone of the session while other clones are
    /// submitting: a submission that races the shutdown either completes
    /// normally (workers drain their queues before exiting) or fails fast
    /// with a typed [`Error::Coordinator`] — never a hang.  The enqueue
    /// path re-checks the shutdown flag under the queue lock precisely so
    /// this race window is closed (see `Coordinator::try_submit`); the
    /// regression is pinned by `tests/service_tier.rs`.  Subsequent
    /// submissions fail fast; metrics, energy attribution, and cached
    /// plans remain readable.
    pub fn shutdown(&self) {
        if let EngineState::Pool { pool, .. } = &self.core.state {
            // A poisoned lock means a tenant panicked mid-submission; the
            // teardown must still run (workers would otherwise leak).
            pool.lock().unwrap_or_else(PoisonError::into_inner).shutdown();
        }
    }

    /// True once [`PsramSession::shutdown`] has run on a pooled engine
    /// (always `false` for exact/single-array sessions).
    pub fn is_shut(&self) -> bool {
        match &self.core.state {
            EngineState::Pool { pool, .. } => {
                pool.lock().unwrap_or_else(PoisonError::into_inner).is_shut()
            }
            _ => false,
        }
    }
}

/// A `(session, job)` submission handle — the unit of multi-tenancy.
/// Clone one per concurrent decomposition job; all clones share the
/// session's device (executor or pool), while plans and metrics stay
/// namespaced per job.
#[derive(Clone)]
pub struct SessionJob {
    core: Arc<SessionCore>,
    id: JobId,
}

impl SessionJob {
    /// This handle's job id.
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Run a kernel under this job and return the result matrix.
    pub fn run(&self, kernel: Kernel<'_>) -> Result<Matrix> {
        if matches!(self.core.state, EngineState::Exact) {
            let out = kernel.run_exact()?;
            self.charge_request();
            return Ok(out);
        }
        let plan = self.resolve_plan(&kernel)?;
        let mut out = Matrix::zeros(plan.out_rows, plan.out_cols);
        match self.execute(&plan, &mut out) {
            Ok(()) => {}
            Err(e) => self.fallback(&kernel, e, &mut out)?,
        }
        Ok(out)
    }

    /// Run a kernel under this job into a caller-provided output (must
    /// match the kernel's result dimensions; zeroed here).  With a warm
    /// plan cache this is the steady-state hot path: no planning, no
    /// output allocation, in-place operand requantization only.
    pub fn run_into(&self, kernel: Kernel<'_>, out: &mut Matrix) -> Result<()> {
        if matches!(self.core.state, EngineState::Exact) {
            let m = kernel.run_exact()?;
            if out.rows() != m.rows() || out.cols() != m.cols() {
                return Err(Error::shape(format!(
                    "output is {}x{} but kernel produces {}x{}",
                    out.rows(),
                    out.cols(),
                    m.rows(),
                    m.cols()
                )));
            }
            out.data_mut().copy_from_slice(m.data());
            self.charge_request();
            return Ok(());
        }
        let plan = self.resolve_plan(&kernel)?;
        match self.execute(&plan, out) {
            Ok(()) => Ok(()),
            Err(e) => self.fallback(&kernel, e, out),
        }
    }

    /// Score the exact plan this job's `run` would execute — see
    /// [`PsramSession::predict`].  With caching enabled this resolves
    /// (and warms) the same cache slot `run` uses, so the scored plan and
    /// the executed plan are one object.  On the exact engine the
    /// estimate is the device model's forecast for this kernel (the
    /// exact engine itself executes no cycles), and the cache is never
    /// warmed — `run` will not read it.
    pub fn predict(&self, kernel: &Kernel<'_>) -> Result<PlanEstimate> {
        let plan = if matches!(self.core.state, EngineState::Exact) {
            self.core.lock_cache().plan_fresh(kernel)?
        } else {
            self.resolve_plan(kernel)?
        };
        self.core.model.predict_plan(&plan)
    }

    /// Resolve the plan for one submission: through the job's cache
    /// namespace (requantized in place on reuse) or freshly planned under
    /// `CachePolicy::Disabled`.  Returns an `Arc`-backed handle (O(1)
    /// clone) so the cache lock is released before execution — one
    /// tenant's running kernel never blocks another tenant's planning.
    fn resolve_plan(&self, kernel: &Kernel<'_>) -> Result<TilePlan> {
        let mut cache = self.core.lock_cache();
        match self.core.policy {
            CachePolicy::Enabled => Ok(cache.plan_kernel(self.id.0, kernel)?.clone()),
            CachePolicy::Disabled => cache.plan_fresh(kernel),
        }
    }

    /// Point-in-time counters of this job.
    pub fn metrics(&self) -> JobSnapshot {
        self.core.metrics().job_snapshot(self.id.0)
    }

    /// Analytic energy attribution of this job — see
    /// [`PsramSession::job_energy`].
    pub fn job_energy(&self) -> EnergyBreakdown {
        PsramSession { core: Arc::clone(&self.core) }.job_energy(self.id)
    }

    /// Drop this job's cached plans.
    pub fn clear(&self) {
        self.core.lock_cache().clear_job(self.id.0);
    }

    /// Execute a resolved plan on the session's engine, charging this
    /// job's metrics.  Transient faults ([`Error::Fault`]) on the
    /// single-array engine are retried in place with the session's
    /// backoff, up to [`FaultPolicy::retries`]; the coordinated engine
    /// retries at batch granularity inside the pool.
    fn execute(&self, plan: &TilePlan, out: &mut Matrix) -> Result<()> {
        match &self.core.state {
            EngineState::Exact => unreachable!("exact engine handled by callers"),
            EngineState::Single { metrics, state } => {
                let fault = self.core.fault;
                let mut attempt = 0u32;
                loop {
                    // A poisoned executor lock means a prior kernel
                    // panicked mid-execution; surface a typed error to
                    // this tenant instead of propagating the panic.
                    let mut st = state.lock().map_err(|_| {
                        Error::Runtime(
                            "session executor poisoned by a prior panic; \
                             rebuild the session"
                                .to_string(),
                        )
                    })?;
                    let mut stats = MttkrpStats::default();
                    let SingleState { exec, scratch } = &mut *st;
                    let res = execute_plan_into(exec, plan, scratch, &mut stats, out);
                    // Charge what actually ran — even on failure, matching
                    // the coordinator workers — plus any integrity-scrub
                    // recovery the executor performed, before deciding on
                    // a retry.
                    let jm = metrics.charge(0, self.id.0, &stats);
                    let rec = exec.drain_recovery();
                    metrics.charge_recovery(self.id.0, &rec);
                    match res {
                        Ok(()) => {
                            // Same counter layout as a coordinator worker
                            // plus the leader's request/batch bookkeeping
                            // (one batch per single-array submission).
                            metrics.add(&metrics.requests, 1);
                            metrics.add(&metrics.batches, 1);
                            metrics.add(&metrics.shard(0).batches, 1);
                            metrics.add(&jm.requests, 1);
                            metrics.add(&jm.batches, 1);
                            return Ok(());
                        }
                        Err(e) if e.is_transient_fault() && attempt < fault.retries => {
                            metrics.add(&metrics.batch_retries, 1);
                            metrics.add(&jm.retries, 1);
                            // Never sleep holding the device lock.
                            drop(st);
                            fault.backoff.wait(attempt);
                            attempt += 1;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
            EngineState::Pool { pool, .. } => {
                let mut pool = pool.lock().map_err(|_| {
                    Error::coordinator(
                        "session pool lock poisoned by a prior panic; \
                         rebuild the session",
                    )
                })?;
                pool.execute_plan_into_for(plan, self.id.0, out)
            }
        }
    }

    /// Graceful degradation: when recovery is exhausted and the session's
    /// [`FaultPolicy::fallback`] allows it, reroute the submission to the
    /// exact digital engine ([`Kernel::run_exact`]).  Only fault-class
    /// errors qualify — anything else (shape/config errors) would fail
    /// identically there.  The reroute is counted in
    /// [`crate::coordinator::JobSnapshot::fallbacks`].
    fn fallback(&self, kernel: &Kernel<'_>, err: Error, out: &mut Matrix) -> Result<()> {
        let rerouteable = matches!(err, Error::Fault(_) | Error::Coordinator(_));
        if !self.core.fault.fallback || !rerouteable {
            return Err(err);
        }
        let m = kernel.run_exact()?;
        if out.rows() != m.rows() || out.cols() != m.cols() {
            return Err(Error::shape(format!(
                "output is {}x{} but kernel produces {}x{}",
                out.rows(),
                out.cols(),
                m.rows(),
                m.cols()
            )));
        }
        out.data_mut().copy_from_slice(m.data());
        // The submission completed (digitally): count the request and the
        // reroute on the engine's metrics.
        let metrics = self.core.metrics();
        let jm = metrics.job(self.id.0);
        metrics.add(&metrics.requests, 1);
        metrics.add(&jm.requests, 1);
        metrics.add(&jm.fallbacks, 1);
        Ok(())
    }

    /// Count a request on the exact engine (no cycles to meter).
    fn charge_request(&self) {
        let m = &self.core.exact_metrics;
        m.add(&m.requests, 1);
        let jm = m.job(self.id.0);
        m.add(&jm.requests, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::pipeline::PsramPipeline;
    use crate::mttkrp::SparsePsramPipeline;
    use crate::tensor::{CooTensor, DenseTensor};
    use crate::tucker::backend::TtmStream;
    use crate::util::prng::Prng;

    // Sessions must be shareable across tenant threads.
    #[allow(dead_code)]
    fn assert_thread_safe() {
        fn check<T: Send + Sync>() {}
        check::<PsramSession>();
        check::<SessionJob>();
    }

    fn problem(seed: u64, shape: &[usize], r: usize) -> (DenseTensor, Vec<Matrix>) {
        let mut rng = Prng::new(seed);
        let x = DenseTensor::randn(shape, &mut rng);
        let factors = shape.iter().map(|&d| Matrix::randn(d, r, &mut rng)).collect();
        (x, factors)
    }

    #[test]
    fn single_array_session_matches_pipeline_bit_exactly() {
        let (x, factors) = problem(1, &[30, 11, 7], 6);
        let session = PsramSession::builder().build().unwrap();
        for mode in 0..3 {
            let got = session
                .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode })
                .unwrap();
            let mut exec = CpuTileExecutor::paper();
            let want = PsramPipeline::new(&mut exec).mttkrp(&x, &factors, mode).unwrap();
            assert_eq!(got.data(), want.data(), "mode {mode}");
        }
        // Cached second pass stays bit-identical.
        let got = session
            .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 })
            .unwrap();
        let mut exec = CpuTileExecutor::paper();
        let want = PsramPipeline::new(&mut exec).mttkrp(&x, &factors, 0).unwrap();
        assert_eq!(got.data(), want.data());
        assert_eq!(session.cached_plans(), 3);
    }

    #[test]
    fn sparse_session_matches_sparse_pipeline_bit_exactly() {
        let mut rng = Prng::new(2);
        let x = CooTensor::random(&[24, 300, 10], 600, &mut rng);
        let factors: Vec<Matrix> =
            [24, 300, 10].iter().map(|&d| Matrix::randn(d, 8, &mut rng)).collect();
        let session = PsramSession::builder().build().unwrap();
        let got = session
            .run(Kernel::SparseMttkrp { x: &x, factors: &factors, mode: 0 })
            .unwrap();
        let mut exec = CpuTileExecutor::paper();
        let want = SparsePsramPipeline::new(&mut exec).mttkrp(&x, &factors, 0).unwrap();
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn coordinated_session_bit_identical_to_single_array() {
        let (x, factors) = problem(3, &[60, 9, 40], 20);
        let single = PsramSession::builder().build().unwrap();
        let pooled = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 3 })
            .build()
            .unwrap();
        for mode in 0..3 {
            let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
            let a = single.run(k).unwrap();
            let b = pooled.run(k).unwrap();
            assert_eq!(a.data(), b.data(), "mode {mode}");
        }
    }

    #[test]
    fn exact_engine_runs_references() {
        let (x, factors) = problem(4, &[8, 7, 6], 3);
        let session =
            PsramSession::builder().engine(Engine::Exact).build().unwrap();
        let got = session
            .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 2 })
            .unwrap();
        let want = crate::mttkrp::reference::dense_mttkrp(&x, &factors, 2).unwrap();
        assert_eq!(got.data(), want.data());
        assert_eq!(session.metrics().snapshot()[0].1, 1); // requests
        assert_eq!(session.job_metrics(JobId::DEFAULT).requests, 1);
        assert_eq!(session.job_metrics(JobId::DEFAULT).total_cycles(), 0);
    }

    #[test]
    fn run_into_reuses_buffer_and_validates_dims() {
        let (x, factors) = problem(5, &[20, 8, 6], 4);
        let session = PsramSession::builder().build().unwrap();
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let fresh = session.run(k).unwrap();
        let mut out = Matrix::zeros(20, 4);
        out.data_mut().fill(7.0);
        session.run_into(k, &mut out).unwrap();
        assert_eq!(out.data(), fresh.data());
        let mut bad = Matrix::zeros(19, 4);
        assert!(session.run_into(k, &mut bad).is_err());
        // Exact engine validates too.
        let exact = PsramSession::builder().engine(Engine::Exact).build().unwrap();
        let mut out = Matrix::zeros(20, 4);
        exact.run_into(k, &mut out).unwrap();
        let mut bad = Matrix::zeros(4, 20);
        assert!(exact.run_into(k, &mut bad).is_err());
    }

    #[test]
    fn predict_is_cycle_exact_against_measured_metrics() {
        let (x, factors) = problem(6, &[52, 10, 30], 40);
        for engine in [Engine::SingleArray, Engine::Coordinated { shards: 2 }] {
            let session = PsramSession::builder().engine(engine).build().unwrap();
            let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
            let est = session.predict(&k).unwrap();
            session.run(k).unwrap();
            let m = session.job_metrics(JobId::DEFAULT);
            assert_eq!(est.images, m.images, "{engine:?}");
            assert_eq!(est.compute_cycles, m.streamed_cycles, "{engine:?}");
            assert_eq!(est.reconfig_write_cycles, m.reconfig_write_cycles);
            assert_eq!(est.useful_macs, m.useful_macs);
            assert_eq!(est.raw_macs, m.raw_macs);
        }
    }

    #[test]
    fn cache_disabled_is_bit_identical_to_enabled() {
        let (x, _) = problem(7, &[18, 9, 8], 5);
        let mut rng = Prng::new(77);
        let cached = PsramSession::builder().build().unwrap();
        let uncached = PsramSession::builder()
            .cache(CachePolicy::Disabled)
            .build()
            .unwrap();
        for _iter in 0..2 {
            let factors: Vec<Matrix> =
                [18, 9, 8].iter().map(|&d| Matrix::randn(d, 5, &mut rng)).collect();
            for mode in 0..3 {
                let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
                let a = cached.run(k).unwrap();
                let b = uncached.run(k).unwrap();
                assert_eq!(a.data(), b.data(), "mode {mode}");
            }
        }
        assert_eq!(cached.cached_plans(), 3);
        assert_eq!(uncached.cached_plans(), 0);
    }

    #[test]
    fn noisy_sessions_are_deterministic_twins() {
        let (x, factors) = problem(8, &[26, 8, 8], 4);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let mk = || {
            PsramSession::builder()
                .noise(NoiseMode::Gaussian { sigma_lsb: 1.0, seed: 11 })
                .build()
                .unwrap()
        };
        let a = mk().run(k).unwrap();
        let b = mk().run(k).unwrap();
        assert_eq!(a.data(), b.data(), "same seed, same bits");
        let ideal = PsramSession::builder().build().unwrap().run(k).unwrap();
        assert_ne!(a.data(), ideal.data(), "noise must perturb the result");
    }

    #[test]
    fn ttm_kernel_matches_exact_within_quant_bound() {
        let mut rng = Prng::new(9);
        let x = DenseTensor::randn(&[12, 7, 5], &mut rng);
        let u = Matrix::randn(12, 4, &mut rng);
        let session = PsramSession::builder().build().unwrap();
        let k = Kernel::Ttm { stream: TtmStream::Fixed(&x, 0), u: &u, slot: 0 };
        let approx = session.run(k).unwrap();
        let exact = k.run_exact().unwrap();
        assert_eq!((approx.rows(), approx.cols()), (35, 4));
        let xt = x.unfold(0).unwrap().transpose();
        let kdim = xt.cols() as f32;
        let (sx, sw) = (xt.max_abs() / 127.0, u.max_abs() / 127.0);
        let bound = (kdim
            * (sx * u.max_abs() / 2.0 + sw * xt.max_abs() / 2.0 + sx * sw / 4.0))
            .max(1e-4);
        for (e, a) in exact.data().iter().zip(approx.data()) {
            assert!((e - a).abs() <= bound);
        }
    }

    #[test]
    fn builder_rejects_invalid_configurations() {
        // Zero shards.
        assert!(PsramSession::builder()
            .engine(Engine::Coordinated { shards: 0 })
            .build()
            .is_err());
        // Analog comb overflow.
        let mut m = PerfModel::paper();
        m.wavelengths = 104;
        assert!(PsramSession::builder().model(m).analog(true).build().is_err());
        // Custom executor with a pool engine.
        assert!(PsramSession::builder()
            .engine(Engine::Coordinated { shards: 2 })
            .executor(Box::new(CpuTileExecutor::paper()))
            .build()
            .is_err());
        // Custom executor with mismatched geometry.
        assert!(PsramSession::builder()
            .executor(Box::new(CpuTileExecutor::new(128, 16, 52)))
            .build()
            .is_err());
    }

    use crate::fault::{
        silence_injected_death_panics, Backoff, FaultEvent, FaultKind, FaultPlan,
    };

    fn one_event(kind: FaultKind) -> Arc<FaultInjector> {
        Arc::new(FaultInjector::new(&FaultPlan::new(
            5,
            vec![FaultEvent { worker: 0, load_idx: 0, kind }],
        )))
    }

    fn transients(n: u64) -> Arc<FaultInjector> {
        Arc::new(FaultInjector::new(&FaultPlan::new(
            6,
            (0..n)
                .map(|i| FaultEvent {
                    worker: 0,
                    load_idx: i,
                    kind: FaultKind::Transient,
                })
                .collect(),
        )))
    }

    #[test]
    fn single_engine_retries_injected_transients_transparently() {
        let (x, factors) = problem(11, &[20, 8, 8], 6);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let clean = PsramSession::builder().build().unwrap().run(k).unwrap();
        let inj = one_event(FaultKind::Transient);
        let session = PsramSession::builder()
            .fault_injector(Arc::clone(&inj))
            .fault_policy(FaultPolicy {
                backoff: Backoff::none(),
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let got = session.run(k).unwrap();
        assert_eq!(got.data(), clean.data(), "retried result must stay bit-exact");
        assert_eq!(inj.injected(), (0, 1, 0));
        let jm = session.job_metrics(JobId::DEFAULT);
        assert_eq!(jm.retries, 1);
        assert_eq!(jm.requests, 1);
    }

    #[test]
    fn scrub_keeps_predict_cycle_exact_under_upsets() {
        let (x, factors) = problem(12, &[20, 8, 8], 6);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let clean = PsramSession::builder().build().unwrap().run(k).unwrap();
        let inj = one_event(FaultKind::ImageUpset { bits: 3 });
        let session = PsramSession::builder()
            .fault_injector(Arc::clone(&inj))
            .build()
            .unwrap();
        let est = session.predict(&k).unwrap();
        let got = session.run(k).unwrap();
        assert_eq!(got.data(), clean.data(), "scrubbed result must stay bit-exact");
        assert_eq!(inj.injected(), (1, 0, 0));
        let jm = session.job_metrics(JobId::DEFAULT);
        assert_eq!(jm.scrubs, 1);
        assert_eq!(jm.scrub_write_cycles, 256);
        // Recovery cost is charged outside the fault-free census:
        // predict==measured still holds under injected upsets.
        assert_eq!(est.compute_cycles, jm.streamed_cycles);
        assert_eq!(est.reconfig_write_cycles, jm.reconfig_write_cycles);
    }

    #[test]
    fn exhausted_recovery_falls_back_to_exact_digital_engine() {
        let (x, factors) = problem(13, &[20, 8, 8], 6);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        // retries=1 allows 2 attempts; 2 injected transients exhaust them.
        let session = PsramSession::builder()
            .fault_injector(transients(2))
            .fault_policy(FaultPolicy {
                retries: 1,
                backoff: Backoff::none(),
                fallback: true,
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let got = session.run(k).unwrap();
        let exact = k.run_exact().unwrap();
        assert_eq!(got.data(), exact.data(), "fallback must be the exact result");
        let jm = session.job_metrics(JobId::DEFAULT);
        assert_eq!(jm.fallbacks, 1);
        assert_eq!(jm.retries, 1);
        assert_eq!(jm.requests, 1);
        // Without fallback the same schedule is a typed error, not a
        // silently wrong result.
        let strict = PsramSession::builder()
            .fault_injector(transients(2))
            .fault_policy(FaultPolicy {
                retries: 1,
                backoff: Backoff::none(),
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let err = strict.run(k).unwrap_err();
        assert!(err.is_transient_fault(), "{err}");
    }

    #[test]
    fn coordinated_session_heals_injected_worker_death() {
        silence_injected_death_panics();
        let (x, factors) = problem(14, &[20, 8, 8], 6);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let clean = PsramSession::builder().build().unwrap().run(k).unwrap();
        let inj = one_event(FaultKind::WorkerDeath);
        let session = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 1 })
            .fault_injector(Arc::clone(&inj))
            .fault_policy(FaultPolicy {
                backoff: Backoff::none(),
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let got = session.run(k).unwrap();
        assert_eq!(got.data(), clean.data(), "healed pool must stay bit-exact");
        assert_eq!(inj.injected(), (0, 0, 1));
        use std::sync::atomic::Ordering;
        let m = session.metrics();
        assert_eq!(m.worker_deaths.load(Ordering::Relaxed), 1);
        assert_eq!(m.worker_respawns.load(Ordering::Relaxed), 1);
        assert_eq!(m.requeued_batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn job_energy_attribution_scales_with_work() {
        let (x, factors) = problem(10, &[40, 8, 8], 8);
        let session = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 2 })
            .build()
            .unwrap();
        let j1 = session.job(JobId(1));
        let j2 = session.job(JobId(2));
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        j1.run(k).unwrap();
        j2.run(k).unwrap();
        j2.run(k).unwrap();
        let e1 = session.job_energy(JobId(1)).total_j();
        let e2 = session.job_energy(JobId(2)).total_j();
        assert!(e1 > 0.0);
        assert!(e2 > e1, "twice the work must cost more energy: {e2} vs {e1}");
    }
}
