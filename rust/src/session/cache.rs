//! The unified plan cache: one keyed store subsuming the three legacy
//! per-kernel caches (`mttkrp::cache`), with per-job namespaces.
//!
//! Keys are `(job, kernel kind, slot)` — see [`PlanKey`]:
//!
//! * the **kind** ([`super::KernelKind`]) separates planner families, so
//!   a dense MTTKRP plan and a TTM plan of *identical* tile geometry
//!   (same `out_rows`/`out_cols`/`stored_len`) can never alias — their
//!   streamed payloads differ even when every dimension matches;
//! * the **job** namespace isolates tenants: two jobs decomposing
//!   different tensors of the same shape reuse only their *own* cached
//!   streams (same-shape aliasing across jobs is impossible by key);
//! * the **slot** is the kernel's mode (MTTKRP) or chain position (TTM).
//!
//! Reuse rules are inherited verbatim from the legacy caches — a cached
//! plan is requantized in place (`replan_into`) when the operand
//! dimensions still match, replanned from scratch otherwise — so cached
//! session trajectories are bit-identical to planning fresh every call
//! (pinned in `tests/session_api.rs`).
//!
//! Contract (unchanged from the legacy caches, now per *(job, slot)*):
//! one `(job, kind, slot)` serves **one** operand identity.  Swapping in
//! a different tensor of identical dimensions under the same key is
//! undetectable; use a fresh [`super::JobId`] per decomposition job, or
//! [`PlanCache::clear_job`] when recycling one.

use super::kernel::{Kernel, KernelKind};
use crate::mttkrp::plan::{DensePlanner, SparseSlicePlanner, TilePlan, TtmPlanner};
use crate::tensor::{krp_all_but, CooTensor, DenseTensor, Matrix};
use crate::tucker::backend::TtmStream;
use crate::util::error::{Error, Result};
use std::collections::HashMap;

/// Key of one cached plan: tenant job × planner family × slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Tenant job namespace (`JobId.0`).
    pub job: u64,
    /// Planner family — dense/sparse/TTM plans never alias.
    pub kind: KernelKind,
    /// Mode (MTTKRP) or chain slot (TTM) within the namespace.
    pub slot: usize,
}

/// One cached plan plus the provenance of its streamed payload.
#[derive(Debug)]
struct CachedPlan {
    plan: TilePlan,
    /// TTM entries only: `Some(mode)` when the cached streams were last
    /// quantized from the fixed decomposition target's `mode` unfolding,
    /// `None` after a changing-stream fill.  A fixed-stream reuse is
    /// only allowed when the mode matches — dimension checks alone
    /// cannot tell two unfold modes of a cube tensor apart, and serving
    /// the wrong mode's streams would be a silent wrong answer.
    /// MTTKRP entries always store `None` (their slot *is* the mode).
    fixed_mode: Option<usize>,
}

/// The unified, job-namespaced plan store of a session.  All three
/// planner families share one tile geometry (the session's array model).
#[derive(Debug)]
pub struct PlanCache {
    dense: DensePlanner,
    sparse: SparseSlicePlanner,
    ttm: TtmPlanner,
    plans: HashMap<PlanKey, CachedPlan>,
}

impl PlanCache {
    /// An empty cache planning for the given tile geometry.
    pub fn new(rows: usize, wpr: usize, lanes: usize) -> Self {
        PlanCache {
            dense: DensePlanner::new(rows, wpr, lanes),
            sparse: SparseSlicePlanner::new(rows, wpr, lanes),
            ttm: TtmPlanner::new(rows, wpr, lanes),
            plans: HashMap::new(),
        }
    }

    /// Cached plans currently held (across all jobs).
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    /// True when no plan is cached.
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Drop every cached plan, all jobs.
    pub fn clear(&mut self) {
        self.plans.clear();
    }

    /// Drop every plan of one job's namespace, leaving other tenants'
    /// warm plans untouched.
    pub fn clear_job(&mut self, job: u64) {
        self.plans.retain(|k, _| k.job != job);
    }

    /// The plan for `kernel` under job `job`: requantized in place when
    /// the cached shape still fits (ALS/HOOI iterations 2..N), planned
    /// from scratch otherwise.  Bit-identical to [`PlanCache::plan_fresh`]
    /// with the same operands.
    pub fn plan_kernel(&mut self, job: u64, kernel: &Kernel<'_>) -> Result<&TilePlan> {
        let key = PlanKey { job, kind: kernel.kind(), slot: kernel.slot() };
        match kernel {
            Kernel::DenseMttkrp { x, factors, mode } => {
                self.plan_dense(key, x, factors, *mode)
            }
            Kernel::SparseMttkrp { x, factors, mode } => {
                self.plan_sparse(key, x, factors, *mode)
            }
            Kernel::Ttm { stream, u, .. } => match stream {
                TtmStream::Fixed(x, mode) => self.plan_ttm_fixed(key, x, *mode, u),
                TtmStream::Changing(xt) => self.plan_ttm_streamed(key, xt, u),
            },
        }
    }

    /// Plan `kernel` without consulting or touching the store
    /// (`CachePolicy::Disabled`, and `predict` on cold sessions that must
    /// not warm tenant namespaces).
    pub fn plan_fresh(&self, kernel: &Kernel<'_>) -> Result<TilePlan> {
        match kernel {
            Kernel::DenseMttkrp { x, factors, mode } => {
                self.dense.plan_mttkrp(x, factors, *mode)
            }
            Kernel::SparseMttkrp { x, factors, mode } => {
                self.sparse.plan(x, factors, *mode)
            }
            Kernel::Ttm { stream, u, .. } => match stream {
                TtmStream::Fixed(x, mode) => {
                    let xt = x.unfold(*mode)?.transpose();
                    self.ttm.plan_streamed(&xt, u)
                }
                TtmStream::Changing(xt) => self.ttm.plan_streamed(xt, u),
            },
        }
    }

    /// Dense MTTKRP slot: reusable when the contraction length, rank,
    /// and output mode dimension all still match — then only the KRP
    /// images are requantized (the tensor's unfolding and streamed codes
    /// are fixed per mode).
    fn plan_dense(
        &mut self,
        key: PlanKey,
        x: &DenseTensor,
        factors: &[Matrix],
        mode: usize,
    ) -> Result<&TilePlan> {
        if mode >= x.ndim() {
            return Err(Error::shape(format!(
                "mode {mode} of {}-mode tensor",
                x.ndim()
            )));
        }
        let krp = krp_all_but(factors, mode)?;
        let reusable = match self.plans.get(&key) {
            Some(entry) => {
                entry.plan.stored_len() == krp.rows()
                    && entry.plan.out_cols == krp.cols()
                    && entry.plan.out_rows == x.shape()[mode]
            }
            None => false,
        };
        if reusable {
            let entry = self.plans.get_mut(&key).expect("checked above");
            self.dense.replan_into(None, &krp, &mut entry.plan)?;
        } else {
            let unf = x.unfold(mode)?;
            let plan = self.dense.plan_unfolded(&unf, &krp)?;
            self.plans.insert(key, CachedPlan { plan, fixed_mode: None });
        }
        Ok(&self.plans.get(&key).expect("just planned").plan)
    }

    /// Sparse MTTKRP slot: reusable when rank and the output/stored
    /// factor dimensions match — then the stored factor images and CP2
    /// scale vectors are refilled in place (fiber codes depend only on
    /// the tensor, which ALS never changes).
    fn plan_sparse(
        &mut self,
        key: PlanKey,
        x: &CooTensor,
        factors: &[Matrix],
        mode: usize,
    ) -> Result<&TilePlan> {
        let nd = factors.len();
        let reusable = match self.plans.get(&key) {
            Some(entry) if nd >= 2 && mode < nd => {
                let m1 = (0..nd).find(|&m| m != mode).expect("nd >= 2");
                factors[0].cols() == entry.plan.out_cols
                    && factors[mode].rows() == entry.plan.out_rows
                    && factors[m1].rows() == entry.plan.stored_len()
            }
            _ => false,
        };
        if reusable {
            let entry = self.plans.get_mut(&key).expect("checked above");
            self.sparse.replan_into(factors, mode, &mut entry.plan)?;
        } else {
            let plan = self.sparse.plan(x, factors, mode)?;
            self.plans.insert(key, CachedPlan { plan, fixed_mode: None });
        }
        Ok(&self.plans.get(&key).expect("just planned").plan)
    }

    /// Fixed-stream TTM slot (the streamed operand is the decomposition
    /// target): warm calls skip the unfolding, the transpose, and the
    /// whole stream requantization — only the stored factor images are
    /// refilled.
    fn plan_ttm_fixed(
        &mut self,
        key: PlanKey,
        x: &DenseTensor,
        mode: usize,
        u: &Matrix,
    ) -> Result<&TilePlan> {
        if mode >= x.ndim() {
            return Err(Error::shape(format!(
                "TTM mode {mode} of {}-mode tensor",
                x.ndim()
            )));
        }
        let rest: usize = x
            .shape()
            .iter()
            .enumerate()
            .filter(|&(m, _)| m != mode)
            .map(|(_, &d)| d)
            .product();
        // Layout reuse needs the dimensions to match; *skipping the
        // stream requantization* additionally needs the cached streams to
        // have come from this exact mode's unfolding (`fixed_mode`) —
        // a cube tensor's modes are dimension-indistinguishable.
        let layout_ok = match self.plans.get(&key) {
            Some(entry) => {
                entry.plan.out_rows == rest
                    && entry.plan.stored_len() == u.rows()
                    && entry.plan.out_cols == u.cols()
            }
            None => false,
        };
        if layout_ok {
            let streams_ok = self.plans.get(&key).expect("checked above").fixed_mode
                == Some(mode);
            let entry = self.plans.get_mut(&key).expect("checked above");
            if streams_ok {
                self.ttm.replan_into(None, u, &mut entry.plan)?;
            } else {
                // Same geometry, different provenance: reuse the layout
                // but requantize the streams from this mode's unfolding.
                let xt = x.unfold(mode)?.transpose();
                self.ttm.replan_into(Some(&xt), u, &mut entry.plan)?;
                entry.fixed_mode = Some(mode);
            }
        } else {
            let xt = x.unfold(mode)?.transpose();
            let plan = self.ttm.plan_streamed(&xt, u)?;
            self.plans.insert(key, CachedPlan { plan, fixed_mode: Some(mode) });
        }
        Ok(&self.plans.get(&key).expect("just planned").plan)
    }

    /// Changing-stream TTM slot (an intermediate chain matrix): streams
    /// and images are both requantized in place into the cached arena,
    /// but the plan layout (grouping, arena allocation) is reused.
    fn plan_ttm_streamed(
        &mut self,
        key: PlanKey,
        xt: &Matrix,
        u: &Matrix,
    ) -> Result<&TilePlan> {
        // A changing stream is fully requantized on every call, so layout
        // reuse is safe regardless of what last filled the slot; the
        // provenance tag is reset so a later fixed-stream call on this
        // slot cannot skip its own stream requantization.
        let reusable = match self.plans.get(&key) {
            Some(entry) => {
                entry.plan.out_rows == xt.rows()
                    && entry.plan.stored_len() == u.rows()
                    && entry.plan.out_cols == u.cols()
            }
            None => false,
        };
        if reusable {
            let entry = self.plans.get_mut(&key).expect("checked above");
            self.ttm.replan_into(Some(xt), u, &mut entry.plan)?;
            entry.fixed_mode = None;
        } else {
            let plan = self.ttm.plan_streamed(xt, u)?;
            self.plans.insert(key, CachedPlan { plan, fixed_mode: None });
        }
        Ok(&self.plans.get(&key).expect("just planned").plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::plan::execute_plan;
    use crate::mttkrp::pipeline::CpuTileExecutor;
    use crate::mttkrp::MttkrpStats;
    use crate::util::prng::Prng;

    fn exec_plan(plan: &TilePlan) -> Matrix {
        let mut exec = CpuTileExecutor::paper();
        let mut stats = MttkrpStats::default();
        execute_plan(&mut exec, plan, &mut stats).unwrap()
    }

    #[test]
    fn dense_and_ttm_of_identical_geometry_do_not_alias() {
        // A dense MTTKRP plan and a TTM plan engineered to share every
        // dimension the reuse checks look at (out_rows 6, stored 16,
        // out_cols 4).  If the keys aliased, the second submission would
        // pass the reuse check and stream the first kernel's stale codes.
        let mut rng = Prng::new(1);
        let xd = DenseTensor::randn(&[6, 8, 2], &mut rng);
        let factors: Vec<Matrix> =
            [6, 8, 2].iter().map(|&d| Matrix::randn(d, 4, &mut rng)).collect();
        let xt_src = DenseTensor::randn(&[16, 3, 2], &mut rng);
        let u = Matrix::randn(16, 4, &mut rng);

        let mut cache = PlanCache::new(256, 32, 52);
        let dense_kernel = Kernel::DenseMttkrp { x: &xd, factors: &factors, mode: 0 };
        let ttm_kernel =
            Kernel::Ttm { stream: TtmStream::Fixed(&xt_src, 0), u: &u, slot: 0 };

        // Same job, same slot number, same plan geometry — different kind.
        let d = exec_plan(cache.plan_kernel(0, &dense_kernel).unwrap());
        {
            let plan = cache.plan_kernel(0, &dense_kernel).unwrap();
            assert_eq!((plan.out_rows, plan.out_cols, plan.stored_len()), (6, 4, 16));
        }
        let t = exec_plan(cache.plan_kernel(0, &ttm_kernel).unwrap());
        assert_eq!(cache.len(), 2, "kinds must occupy distinct keys");

        let d_fresh = exec_plan(&cache.plan_fresh(&dense_kernel).unwrap());
        let t_fresh = exec_plan(&cache.plan_fresh(&ttm_kernel).unwrap());
        assert_eq!(d.data(), d_fresh.data());
        assert_eq!(t.data(), t_fresh.data());

        // And the dense slot is still warm and still correct.
        let d2 = exec_plan(cache.plan_kernel(0, &dense_kernel).unwrap());
        assert_eq!(d2.data(), d_fresh.data());
    }

    #[test]
    fn job_namespaces_isolate_same_shape_tensors() {
        // Two jobs decompose *different* tensors of identical shape.  A
        // shared namespace would let job 2 reuse job 1's streamed codes
        // (the dimensions all match); per-job keys make that impossible.
        let mut rng = Prng::new(2);
        let x1 = DenseTensor::randn(&[10, 7, 5], &mut rng);
        let x2 = DenseTensor::randn(&[10, 7, 5], &mut rng);
        let factors: Vec<Matrix> =
            [10, 7, 5].iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
        let k1 = Kernel::DenseMttkrp { x: &x1, factors: &factors, mode: 0 };
        let k2 = Kernel::DenseMttkrp { x: &x2, factors: &factors, mode: 0 };

        let mut cache = PlanCache::new(256, 32, 52);
        let a1 = exec_plan(cache.plan_kernel(1, &k1).unwrap());
        let a2 = exec_plan(cache.plan_kernel(2, &k2).unwrap());
        assert_eq!(cache.len(), 2);
        assert_eq!(a1.data(), exec_plan(&cache.plan_fresh(&k1).unwrap()).data());
        assert_eq!(a2.data(), exec_plan(&cache.plan_fresh(&k2).unwrap()).data());
        assert_ne!(a1.data(), a2.data(), "different tensors, different results");
    }

    #[test]
    fn clear_job_evicts_one_namespace_only() {
        let mut rng = Prng::new(3);
        let x = DenseTensor::randn(&[8, 6, 4], &mut rng);
        let factors: Vec<Matrix> =
            [8, 6, 4].iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
        let mut cache = PlanCache::new(256, 32, 52);
        for mode in 0..3 {
            let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
            cache.plan_kernel(1, &k).unwrap();
            cache.plan_kernel(2, &k).unwrap();
        }
        assert_eq!(cache.len(), 6);
        cache.clear_job(1);
        assert_eq!(cache.len(), 3);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_slots_requantize_bit_identically_across_factor_updates() {
        // The ALS pattern: tensor fixed, factors change every call.  Warm
        // results must equal fresh plans bit for bit, for every kind.
        let mut rng = Prng::new(4);
        let x = DenseTensor::randn(&[20, 9, 8], &mut rng);
        let coo = CooTensor::random(&[24, 300, 10], 500, &mut rng);
        let mut cache = PlanCache::new(256, 32, 52);

        for iter in 0..3 {
            let factors: Vec<Matrix> =
                [20, 9, 8].iter().map(|&d| Matrix::randn(d, 6, &mut rng)).collect();
            let sf: Vec<Matrix> = [24, 300, 10]
                .iter()
                .map(|&d| Matrix::randn(d, 6, &mut rng))
                .collect();
            let u = Matrix::randn(20, 5, &mut rng);
            for (i, k) in [
                Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 },
                Kernel::SparseMttkrp { x: &coo, factors: &sf, mode: 1 },
                Kernel::Ttm { stream: TtmStream::Fixed(&x, 0), u: &u, slot: 0 },
            ]
            .iter()
            .enumerate()
            {
                let warm = exec_plan(cache.plan_kernel(0, k).unwrap());
                let fresh = exec_plan(&cache.plan_fresh(k).unwrap());
                assert_eq!(
                    warm.data(),
                    fresh.data(),
                    "iter {iter} kernel {i} diverged"
                );
            }
        }
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn ttm_mode_flip_on_one_slot_requantizes_streams() {
        // Cube tensor: every unfold mode has identical dimensions, so the
        // reuse checks alone cannot tell them apart.  Flipping the mode
        // on one slot must requantize the streams, not serve mode-0's.
        let mut rng = Prng::new(7);
        let x = DenseTensor::randn(&[12, 12, 12], &mut rng);
        let u = Matrix::randn(12, 4, &mut rng);
        let mut cache = PlanCache::new(256, 32, 52);

        let k0 = Kernel::Ttm { stream: TtmStream::Fixed(&x, 0), u: &u, slot: 0 };
        let k1 = Kernel::Ttm { stream: TtmStream::Fixed(&x, 1), u: &u, slot: 0 };
        let a0 = exec_plan(cache.plan_kernel(0, &k0).unwrap());
        let a1 = exec_plan(cache.plan_kernel(0, &k1).unwrap());
        assert_eq!(a0.data(), exec_plan(&cache.plan_fresh(&k0).unwrap()).data());
        assert_eq!(
            a1.data(),
            exec_plan(&cache.plan_fresh(&k1).unwrap()).data(),
            "mode flip served stale streams"
        );
        // Flip back: provenance must track the latest fill.
        let a0b = exec_plan(cache.plan_kernel(0, &k0).unwrap());
        assert_eq!(a0b.data(), a0.data());
    }

    #[test]
    fn ttm_stream_kind_flip_on_one_slot_requantizes_streams() {
        // Changing then Fixed on the same slot with identical dims: the
        // fixed call must not skip its stream requantization.
        let mut rng = Prng::new(8);
        let x = DenseTensor::randn(&[12, 7, 5], &mut rng);
        let y = DenseTensor::randn(&[12, 7, 5], &mut rng);
        let yt = y.unfold(0).unwrap().transpose();
        let u = Matrix::randn(12, 4, &mut rng);
        let mut cache = PlanCache::new(256, 32, 52);

        let changing = Kernel::Ttm { stream: TtmStream::Changing(&yt), u: &u, slot: 2 };
        let fixed = Kernel::Ttm { stream: TtmStream::Fixed(&x, 0), u: &u, slot: 2 };
        exec_plan(cache.plan_kernel(0, &changing).unwrap());
        let got = exec_plan(cache.plan_kernel(0, &fixed).unwrap());
        assert_eq!(
            got.data(),
            exec_plan(&cache.plan_fresh(&fixed).unwrap()).data(),
            "kind flip served the changing stream's codes"
        );
    }

    #[test]
    fn rank_change_replans_instead_of_reusing() {
        let mut rng = Prng::new(5);
        let x = DenseTensor::randn(&[12, 6, 5], &mut rng);
        let mut cache = PlanCache::new(256, 32, 52);
        let f5: Vec<Matrix> =
            [12, 6, 5].iter().map(|&d| Matrix::randn(d, 5, &mut rng)).collect();
        let k5 = Kernel::DenseMttkrp { x: &x, factors: &f5, mode: 0 };
        assert_eq!(cache.plan_kernel(0, &k5).unwrap().out_cols, 5);
        let f7: Vec<Matrix> =
            [12, 6, 5].iter().map(|&d| Matrix::randn(d, 7, &mut rng)).collect();
        let k7 = Kernel::DenseMttkrp { x: &x, factors: &f7, mode: 0 };
        assert_eq!(cache.plan_kernel(0, &k7).unwrap().out_cols, 7);
    }

    #[test]
    fn out_of_range_modes_rejected() {
        let mut rng = Prng::new(6);
        let x = DenseTensor::randn(&[4, 4, 4], &mut rng);
        let factors: Vec<Matrix> =
            [4, 4, 4].iter().map(|&d| Matrix::randn(d, 2, &mut rng)).collect();
        let u = Matrix::randn(4, 2, &mut rng);
        let mut cache = PlanCache::new(256, 32, 52);
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 3 };
        assert!(cache.plan_kernel(0, &k).is_err());
        let t = Kernel::Ttm { stream: TtmStream::Fixed(&x, 3), u: &u, slot: 0 };
        assert!(cache.plan_kernel(0, &t).is_err());
    }
}
