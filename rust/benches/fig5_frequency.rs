//! FIG5ii — regenerates Fig. 5(ii): sustained MTTKRP performance vs
//! operating frequency at 52 wavelength channels (predictive model; the
//! functional simulator is frequency-agnostic, so frequency enters through
//! the cycle→time conversion, validated here against hand math).

#[path = "common/mod.rs"]
mod common;

use psram_imc::perfmodel::{fig5_frequency, PerfModel, Workload};
use psram_imc::util::stats::linear_fit;
use psram_imc::util::units::format_ops;

fn main() {
    common::section("Fig 5(ii): sustained performance vs operating frequency (model)");
    let clocks: Vec<f64> = vec![1e9, 2e9, 5e9, 8e9, 10e9, 12e9, 15e9, 18e9, 20e9, 25e9];
    let pts = fig5_frequency(&clocks, 52).unwrap();
    println!("{:>8} | {:>16} | {:>8} | {}", "GHz", "sustained", "util", "device");
    for p in &pts {
        println!(
            "{:>8} | {:>16} | {:>8.4} | {}",
            p.x / 1e9,
            format_ops(p.sustained_ops),
            p.utilization,
            if p.admissible { "ok" } else { "over-spec" }
        );
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.x).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.sustained_ops).collect();
    let (_, slope, r2) = linear_fit(&xs, &ys);
    println!("series linearity: R²={r2:.6} slope={slope:.3} ops/Hz");
    assert!(r2 > 0.999, "Fig 5(ii) must be linear");

    common::section("frequency bookkeeping cross-check");
    // At 10 GHz the same cycle counts take exactly 2x the 20 GHz time; the
    // write clock stays at the device's 20 GHz so utilisation *improves*
    // slightly at lower compute clocks (writes overlap fewer compute-clock
    // cycles).  Verify both effects.
    let w = Workload::paper_large();
    let mut m20 = PerfModel::paper();
    m20.clock_hz = 20e9;
    let e20 = m20.predict(&w).unwrap();
    let mut m10 = PerfModel::paper();
    m10.clock_hz = 10e9;
    let e10 = m10.predict(&w).unwrap();
    println!("runtime 20GHz: {:.4e} s, 10GHz: {:.4e} s", e20.runtime_s, e10.runtime_s);
    println!("util    20GHz: {:.5},  10GHz: {:.5}", e20.utilization, e10.utilization);
    assert!(e10.runtime_s > 1.9 * e20.runtime_s);
    assert!(e10.utilization >= e20.utilization);
}
