//! AB-BER — thermal-drift ablation: MRR resonance drift → stored-bit error
//! rate → CP-ALS decomposition quality, plus the heater power required to
//! lock the rings (the mitigation the PDK assumes).

#[path = "common/mod.rs"]
mod common;

use psram_imc::compute::ComputeEngine;
use psram_imc::cpd::{brute_force_fit, AlsConfig, CpAls, PsramBackend};
use psram_imc::device::MicroRing;
use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, TileExecutor};
use psram_imc::psram::PsramArray;
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;

/// An executor whose array suffers bit errors after every image load.
struct FaultyExecutor {
    inner: AnalogTileExecutor,
    ber: f64,
    rng: Prng,
}

impl TileExecutor for FaultyExecutor {
    fn rows(&self) -> usize {
        self.inner.rows()
    }
    fn words_per_row(&self) -> usize {
        self.inner.words_per_row()
    }
    fn max_lanes(&self) -> usize {
        self.inner.max_lanes()
    }
    fn load_image(&mut self, image: &[i8]) -> psram_imc::Result<()> {
        self.inner.load_image(image)?;
        self.inner.array.inject_bit_errors(self.ber, &mut self.rng);
        Ok(())
    }
    fn compute_into(
        &mut self,
        u: &[u8],
        lanes: usize,
        out: &mut [i32],
    ) -> psram_imc::Result<()> {
        self.inner.compute_into(u, lanes, out)
    }
    fn cycles(&self) -> psram_imc::psram::CycleLedger {
        self.inner.cycles()
    }
}

fn main() {
    common::section("AB-BER: thermal drift -> resonance shift -> BER (device model)");
    let ring = MicroRing::gf45spclo_compute_ring();
    println!(
        "{:>8} | {:>12} | {:>10} | {:>10} | {:>12}",
        "ΔT (K)", "shift (pm)", "contrast", "BER", "heater (mW)"
    );
    let mut bers = Vec::new();
    for &dt in &[0.0f64, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0] {
        let shift = ring.thermal_shift_m(dt) / 1e-12;
        let c = ring.thermal_contrast(dt);
        let ber = ring.thermal_ber(dt, 0.5);
        let heater = ring.heater_power_w(dt, 1.0) * 1e3;
        println!("{dt:>8} | {shift:>12.1} | {c:>10.4} | {ber:>10.4} | {heater:>12.2}");
        bers.push((dt, ber));
    }

    common::section("AB-BER: CP-ALS verified fit vs stored-bit error rate");
    let mut rng = Prng::new(55);
    let truth: Vec<Matrix> =
        [20usize, 16, 12].iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
    let x = DenseTensor::from_cp_factors(&truth, 0.0, &mut rng).unwrap();
    println!("{:>10} | {:>12}", "BER", "fit (true)");
    let mut fits = Vec::new();
    for &ber in &[0.0f64, 1e-5, 1e-4, 1e-3, 1e-2, 0.1] {
        let mut best = f64::NEG_INFINITY;
        for seed in [5u64, 6, 7] {
            let exec = FaultyExecutor {
                inner: AnalogTileExecutor::new(ComputeEngine::ideal(), PsramArray::paper()),
                ber,
                rng: Prng::new(1000 + seed),
            };
            let mut backend = PsramBackend::new(&x, exec);
            let res = CpAls::new(AlsConfig { rank: 3, max_iters: 20, tol: 1e-7, seed })
                .run_backend(&mut backend)
                .unwrap();
            best = best.max(brute_force_fit(&x, &res.factors, &res.lambda));
        }
        println!("{ber:>10.1e} | {best:>12.6}");
        fits.push(best);
    }
    assert!(fits[0] > 0.95, "clean fit should be high: {}", fits[0]);
    assert!(
        *fits.last().unwrap() < fits[0],
        "10% BER must degrade the decomposition: {fits:?}"
    );
    println!("\n(a flipped MSB injects ±128-scale outliers; ALS tolerates BER ≲ 1e-4,");
    println!(" i.e. thermal locking to ~±2 K per the device table above)");
}
