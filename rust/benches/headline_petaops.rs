//! HEADLINE — the §V.B claim: 17 PetaOps sustained at 256×256 bits /
//! 52 λ / 20 GHz / 8-bit.  Reproduced from the model, validated against the
//! functional pipeline's measured cycle counts, and accompanied by the
//! simulator's own wall-clock throughput (the L3 perf target).
//!
//! `cargo bench --bench headline_petaops -- --json out.json` mirrors the
//! printed numbers into a machine-readable telemetry report (the
//! committed `BENCH_headline.json` baseline comes from the reduced-size
//! `psram-imc bench-report` suite instead — see `telemetry::suite`).

#[path = "common/mod.rs"]
mod common;

use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor, PsramPipeline};
use psram_imc::perfmodel::{headline, PerfModel, Workload};
use psram_imc::telemetry::{BenchRecord, Direction};
use psram_imc::tensor::Matrix;
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

fn main() {
    let mut rec = common::Recorder::from_args("bench_headline_petaops");

    common::section("headline: peak and sustained at the paper configuration");
    let (peak, sustained, util) = headline().unwrap();
    println!("peak      : {}", format_ops(peak));
    println!("sustained : {} (paper: 17 PetaOps)", format_ops(sustained));
    println!("util      : {util:.4}");
    assert!((peak / 1e15 - 17.04).abs() < 0.01);
    assert!(sustained / peak > 0.98);
    rec.record(
        BenchRecord::new("peak_ops", peak, "ops/s")
            .better(Direction::Higher)
            .tol(1e-6),
    );
    rec.record(
        BenchRecord::new("sustained_ops", sustained, "ops/s")
            .better(Direction::Higher)
            .tol(1e-6),
    );
    rec.record(BenchRecord::new("utilization", util, "ratio").tol(1e-9));

    common::section("model vs measured cycles (reuse-heavy scaled workload)");
    // I = 20800 rows (400 lane batches), K = 512 (2 images), R = 32.
    let mut rng = Prng::new(3);
    let unf = Matrix::randn(20_800, 512, &mut rng);
    let krp = Matrix::randn(512, 32, &mut rng);
    let mut exec = CpuTileExecutor::paper();
    let mut pipe = PsramPipeline::new(&mut exec);
    pipe.mttkrp_unfolded(&unf, &krp).unwrap();
    let est = PerfModel::paper()
        .predict(&Workload { i_rows: 20_800, k_contraction: 512, rank: 32 })
        .unwrap();
    println!(
        "measured: images={} compute={} write={} U={:.4}",
        pipe.stats.images,
        pipe.stats.compute_cycles,
        pipe.stats.write_cycles,
        pipe.stats.utilization()
    );
    println!(
        "model   : images={} compute={} write={} U={:.4}",
        est.images, est.compute_cycles, est.write_cycles, est.utilization
    );
    assert_eq!(est.images, pipe.stats.images);
    assert_eq!(est.compute_cycles, pipe.stats.compute_cycles);
    assert_eq!(est.write_cycles, pipe.stats.write_cycles);
    rec.record(BenchRecord::new(
        "scaled.measured_images",
        pipe.stats.images as f64,
        "images",
    ));
    rec.record(BenchRecord::new(
        "scaled.measured_compute_cycles",
        pipe.stats.compute_cycles as f64,
        "cycles",
    ));
    rec.record(BenchRecord::new(
        "scaled.measured_write_cycles",
        pipe.stats.write_cycles as f64,
        "cycles",
    ));
    rec.record(
        BenchRecord::new("scaled.measured_utilization", pipe.stats.utilization(), "ratio")
            .tol(1e-9),
    );

    common::section("simulator wall-clock throughput (L3 perf target)");
    // CPU integer executor (the optimized digital hot path):
    let macs = pipe.stats.useful_macs as f64;
    let t_cpu = rec.timed("cpu-executor mttkrp 20800x512x32", 1, 5, || {
        let mut e = CpuTileExecutor::paper();
        let mut p = PsramPipeline::new(&mut e);
        p.mttkrp_unfolded(&unf, &krp).unwrap();
    });
    println!("  cpu executor    : {:.3e} MAC/s", macs / t_cpu.median);
    rec.record(
        BenchRecord::new("cpu_executor_mac_per_s", macs / t_cpu.median, "MAC/s")
            .better(Direction::Higher)
            .wall_clock()
            .samples(t_cpu.n),
    );
    // Analog simulator (device-faithful fast path):
    let t_sim = rec.timed("analog-sim mttkrp 20800x512x32", 1, 3, || {
        let mut e = AnalogTileExecutor::ideal();
        let mut p = PsramPipeline::new(&mut e);
        p.mttkrp_unfolded(&unf, &krp).unwrap();
    });
    println!("  analog simulator: {:.3e} MAC/s", macs / t_sim.median);
    rec.record(
        BenchRecord::new("analog_sim_mac_per_s", macs / t_sim.median, "MAC/s")
            .better(Direction::Higher)
            .wall_clock()
            .samples(t_sim.n),
    );

    rec.finish();
}
