//! HEADLINE — the §V.B claim: 17 PetaOps sustained at 256×256 bits /
//! 52 λ / 20 GHz / 8-bit.  Reproduced from the model, validated against the
//! functional pipeline's measured cycle counts, and accompanied by the
//! simulator's own wall-clock throughput (the L3 perf target).

#[path = "common/mod.rs"]
mod common;

use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor, PsramPipeline};
use psram_imc::perfmodel::{headline, PerfModel, Workload};
use psram_imc::tensor::Matrix;
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

fn main() {
    common::section("headline: peak and sustained at the paper configuration");
    let (peak, sustained, util) = headline().unwrap();
    println!("peak      : {}", format_ops(peak));
    println!("sustained : {} (paper: 17 PetaOps)", format_ops(sustained));
    println!("util      : {util:.4}");
    assert!((peak / 1e15 - 17.04).abs() < 0.01);
    assert!(sustained / peak > 0.98);

    common::section("model vs measured cycles (reuse-heavy scaled workload)");
    // I = 20800 rows (400 lane batches), K = 512 (2 images), R = 32.
    let mut rng = Prng::new(3);
    let unf = Matrix::randn(20_800, 512, &mut rng);
    let krp = Matrix::randn(512, 32, &mut rng);
    let mut exec = CpuTileExecutor::paper();
    let mut pipe = PsramPipeline::new(&mut exec);
    pipe.mttkrp_unfolded(&unf, &krp).unwrap();
    let est = PerfModel::paper()
        .predict(&Workload { i_rows: 20_800, k_contraction: 512, rank: 32 })
        .unwrap();
    println!(
        "measured: images={} compute={} write={} U={:.4}",
        pipe.stats.images,
        pipe.stats.compute_cycles,
        pipe.stats.write_cycles,
        pipe.stats.utilization()
    );
    println!(
        "model   : images={} compute={} write={} U={:.4}",
        est.images, est.compute_cycles, est.write_cycles, est.utilization
    );
    assert_eq!(est.images, pipe.stats.images);
    assert_eq!(est.compute_cycles, pipe.stats.compute_cycles);
    assert_eq!(est.write_cycles, pipe.stats.write_cycles);

    common::section("simulator wall-clock throughput (L3 perf target)");
    // CPU integer executor (the optimized digital hot path):
    let macs = pipe.stats.useful_macs as f64;
    let t_cpu = common::bench("cpu-executor mttkrp 20800x512x32", 1, 5, || {
        let mut e = CpuTileExecutor::paper();
        let mut p = PsramPipeline::new(&mut e);
        p.mttkrp_unfolded(&unf, &krp).unwrap();
    });
    println!("  cpu executor    : {:.3e} MAC/s", macs / t_cpu);
    // Analog simulator (device-faithful fast path):
    let t_sim = common::bench("analog-sim mttkrp 20800x512x32", 1, 3, || {
        let mut e = AnalogTileExecutor::ideal();
        let mut p = PsramPipeline::new(&mut e);
        p.mttkrp_unfolded(&unf, &krp).unwrap();
    });
    println!("  analog simulator: {:.3e} MAC/s", macs / t_sim);
}
