//! PROFILE-SWEEP — cross-profile device sweep: for every registry
//! [`DeviceProfile`] the predicted envelope on the paper workload
//! (peak/sustained/utilization, analytic energy per op, link SNR and
//! effective bits), plus a measured X-pSRAM binary-op (XOR) census pinned
//! against `PerfModel::predict_xor` and wall-clock timings of the
//! functional kernels under each profile's engine.

#[path = "common/mod.rs"]
mod common;

use psram_imc::compute::ComputeEngine;
use psram_imc::device::profiles;
use psram_imc::energy::EnergyModel;
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::psram::PsramArray;
use psram_imc::telemetry::{BenchRecord, Direction};
use psram_imc::util::fixed::encode_offset;
use psram_imc::util::prng::Prng;
use psram_imc::util::units::{format_energy, format_ops};

fn main() {
    let mut rec = common::Recorder::from_args("profile_sweep");
    let w = Workload::paper_large();

    common::section("PROFILE-SWEEP: predicted envelope per device profile (model)");
    println!(
        "{:<12} | {:>6} | {:>12} | {:>12} | {:>8} | {:>10} | {:>8} | {:>6}",
        "profile", "GHz", "peak", "sustained", "util", "J/op", "SNR dB", "ENOB"
    );
    let mut sustained = Vec::new();
    for p in profiles::all() {
        let model = PerfModel::from_profile(&p);
        let est = model.predict(&w).unwrap();
        let e = EnergyModel::from_profile(&p).predict(&est);
        let per_op = e.per_op_j(2.0 * w.useful_macs());
        println!(
            "{:<12} | {:>6} | {:>12} | {:>12} | {:>8.4} | {:>10} | {:>8.2} | {:>6.2}",
            p.name,
            model.clock_hz / 1e9,
            format_ops(est.peak_ops),
            format_ops(est.sustained_raw_ops),
            est.utilization,
            format_energy(per_op),
            p.link_snr_db(),
            p.effective_bits(),
        );
        sustained.push((p.name.clone(), est.sustained_raw_ops));
        rec.record(
            BenchRecord::new(
                format!("profile_sweep.{}.sustained_ops", p.name),
                est.sustained_raw_ops,
                "ops/s",
            )
            .better(Direction::Higher)
            .tol(1e-6),
        );
        rec.record(
            BenchRecord::new(
                format!("profile_sweep.{}.energy_per_op_j", p.name),
                per_op,
                "J/op",
            )
            .better(Direction::Lower)
            .tol(1e-6),
        );
    }
    // The sweep's headline ordering: the EO-ADC profile lifts sustained
    // throughput above the paper baseline; X-pSRAM matches baseline on
    // the MAC path (its win is the XOR kernel below).
    let get = |name: &str| sustained.iter().find(|(n, _)| n == name).unwrap().1;
    assert!(get("eo_adc") > get("baseline"), "EO ADC must raise sustained ops");
    assert!(get("x_psram_xor") == get("baseline"), "X-pSRAM MAC path == baseline");

    common::section("PROFILE-SWEEP: X-pSRAM XOR kernel census (measured == predicted)");
    let xp = profiles::x_psram_xor();
    let mut rng = Prng::new(97);
    let mut array = PsramArray::paper();
    let img: Vec<i8> =
        (0..array.geometry().total_words()).map(|_| rng.next_i8()).collect();
    array.write_image(&img).unwrap();
    let rows = array.geometry().rows;
    let wpr = array.geometry().words_per_row();
    let vectors = 208; // 4 full 52-lane cycles
    let bits: Vec<u8> = (0..vectors * rows).map(|_| rng.next_u8() & 1).collect();
    let lane_counts = vec![52usize; vectors / 52];
    let mut out = vec![0u32; vectors * wpr];

    let mut engine = ComputeEngine::from_profile(&xp);
    engine.xor_block_into(&mut array, &bits, &lane_counts, &mut out).unwrap();
    let est = PerfModel::from_profile(&xp).predict_xor(vectors as u64).unwrap();
    assert_eq!(engine.stats.xor_cycles, est.xor_cycles);
    assert_eq!(engine.stats.bit_ops, est.bit_ops);
    println!(
        "xor census: {} cycles, {} bit-ops, predicted sustained {}",
        est.xor_cycles,
        est.bit_ops,
        format_ops(est.sustained_bit_ops)
    );
    rec.record(BenchRecord::new(
        "profile_sweep.xor.measured_cycles",
        engine.stats.xor_cycles as f64,
        "cycles",
    ));
    rec.record(BenchRecord::new(
        "profile_sweep.xor.measured_bit_ops",
        engine.stats.bit_ops as f64,
        "bitops",
    ));

    common::section("PROFILE-SWEEP: functional kernel wall-clock per profile");
    let u: Vec<u8> =
        (0..52 * rows).map(|_| encode_offset(i32::from(rng.next_i8()))).collect();
    for p in profiles::all() {
        let mut engine = ComputeEngine::from_profile(&p);
        let mut arr = PsramArray::paper();
        arr.write_image(&img).unwrap();
        let mut mac_out = vec![0i32; 52 * wpr];
        let stats = common::bench_stats(
            &format!("compute_cycle 52 lanes [{}]", p.name),
            3,
            30,
            || {
                engine.compute_cycle_into(&mut arr, &u, 52, &mut mac_out).unwrap();
            },
        );
        rec.wall(&format!("profile_sweep.{}.compute_cycle_s", p.name), &stats);
    }
    {
        let mut engine = ComputeEngine::from_profile(&xp);
        let mut arr = PsramArray::paper();
        arr.write_image(&img).unwrap();
        let cycle_bits = &bits[..52 * rows];
        let mut xor_out = vec![0u32; 52 * wpr];
        let stats = common::bench_stats("xor_cycle 52 lanes [x_psram_xor]", 3, 30, || {
            engine.xor_cycle_into(&mut arr, cycle_bits, 52, &mut xor_out).unwrap();
        });
        rec.wall("profile_sweep.x_psram_xor.xor_cycle_s", &stats);
    }

    rec.finish();
}
