//! AB-SPARSE — sparse MTTKRP (the paper's motivating kernel) on the pSRAM
//! array: throughput and utilisation vs tensor density, plus CPU sparse
//! baseline comparison.  The *shape* to reproduce: the photonic array wins
//! on reuse-heavy dense workloads; at low density the raw-MAC efficiency
//! collapses to the density (zeros still ride the wavelengths).

#[path = "common/mod.rs"]
mod common;

use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::mttkrp::plan::SparseSlicePlanner;
use psram_imc::mttkrp::reference::sparse_mttkrp;
use psram_imc::mttkrp::{CpuTileExecutor, SparsePsramPipeline};
use psram_imc::perfmodel::PerfModel;
use psram_imc::telemetry::BenchRecord;
use psram_imc::tensor::{CooTensor, Matrix};
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

fn main() {
    let mut rec = common::Recorder::from_args("bench_sparse_mttkrp");
    let mut rng = Prng::new(17);
    let shape = [128usize, 256, 64];
    let total = shape.iter().product::<usize>();
    let rank = 32;
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, rank, &mut rng)).collect();

    common::section("AB-SPARSE: pSRAM sparse MTTKRP vs density (128x256x64, r32)");
    println!(
        "{:>9} | {:>9} | {:>12} | {:>10} | {:>10} | {:>12}",
        "density", "nnz", "wall", "util", "raw eff", "useful MAC/s"
    );
    for &density in &[0.001f64, 0.01, 0.05, 0.2] {
        let nnz = (total as f64 * density) as usize;
        let x = CooTensor::random(&shape, nnz, &mut rng);
        let mut exec = CpuTileExecutor::paper();
        let mut pipe = SparsePsramPipeline::new(&mut exec);
        pipe.mttkrp(&x, &factors, 0).unwrap();
        let stats = pipe.stats;
        let t = rec.timed(&format!("sp-mttkrp density={density}"), 1, 3, || {
            let mut e = CpuTileExecutor::paper();
            SparsePsramPipeline::new(&mut e).mttkrp(&x, &factors, 0).unwrap();
        });
        println!(
            "{density:>9} | {:>9} | {:>12} | {:>10.4} | {:>10.4} | {:>12.3e}",
            x.nnz(),
            common::fmt_s(t.median),
            stats.utilization(),
            stats.padding_efficiency(),
            stats.useful_macs as f64 / t.median
        );
        rec.record(
            BenchRecord::new(
                format!("density{density}.measured_utilization"),
                stats.utilization(),
                "ratio",
            )
            .tol(1e-9),
        );
        rec.record(
            BenchRecord::new(
                format!("density{density}.padding_efficiency"),
                stats.padding_efficiency(),
                "ratio",
            )
            .tol(1e-9),
        );
    }

    common::section("AB-SPARSE: CPU sparse baseline (same workload)");
    for &density in &[0.01f64, 0.2] {
        let nnz = (total as f64 * density) as usize;
        let x = CooTensor::random(&shape, nnz, &mut rng);
        let t = rec.timed(&format!("cpu sparse_mttkrp density={density}"), 1, 5, || {
            sparse_mttkrp(&x, &factors, 0).unwrap();
        });
        println!("  -> {:.3e} useful MAC/s", (x.nnz() * rank) as f64 / t.median);
    }
    println!("\n(expected shape: photonic raw-MAC efficiency ≈ density — the array");
    println!(" computes zeros — while the CPU baseline scales with nnz only; the");
    println!(" crossover argument favours the array only above ~columns/rows density)");

    // ---- coordinator-sharded sweep: slice plans across 1..16 shards ----
    // The slice plan shards by stored factor block (j_dim = 2048 -> 8
    // groups), so up to 8 shards take home batches and stealing covers the
    // rest.  The sustained point from the measured cycle metrics must land
    // exactly on the predict_plan envelope: plan totals are
    // scheduling-independent by construction.
    common::section(
        "AB-SPARSE: coordinator-sharded spMTTKRP (64x2048x16, r32) vs predict_plan",
    );
    let shape2 = [64usize, 2048, 16];
    let total2: usize = shape2.iter().product();
    let factors2: Vec<Matrix> =
        shape2.iter().map(|&d| Matrix::randn(d, rank, &mut rng)).collect();
    let sparse_planner = SparseSlicePlanner::new(256, 32, 52);
    for &density in &[0.001f64, 0.01, 0.05] {
        let nnz = (total2 as f64 * density) as usize;
        let x = CooTensor::random(&shape2, nnz, &mut rng);
        let plan = sparse_planner.plan(&x, &factors2, 0).unwrap();
        println!(
            "\n-- density {density}: {} nnz, {} stored-image groups, {} images --",
            x.nnz(),
            plan.groups.len(),
            plan.total_images()
        );
        for &shards in &[1usize, 2, 4, 8, 16] {
            let mut model = PerfModel::paper();
            model.num_arrays = shards;
            let est = model.predict_plan(&plan).unwrap();
            let t = rec.timed(
                &format!("coord sp-mttkrp d={density} shards={shards:>2}"),
                1,
                3,
                || {
                    let mut pool =
                        Coordinator::spawn(CoordinatorConfig::new(shards), |_| {
                            Ok(CpuTileExecutor::paper())
                        })
                        .unwrap();
                    pool.sparse_mttkrp(&x, &factors2, 0).unwrap();
                },
            );
            // Device-model sustained throughput from one fresh run's
            // metrics, against the predict_plan envelope.
            let mut pool = Coordinator::spawn(CoordinatorConfig::new(shards), |_| {
                Ok(CpuTileExecutor::paper())
            })
            .unwrap();
            pool.sparse_mttkrp(&x, &factors2, 0).unwrap();
            let measured_util = pool.metrics().utilization();
            let in_env = (measured_util - est.utilization).abs() <= 1e-9;
            println!(
                "  -> sustained {} measured vs {} predicted (U {measured_util:.4} \
                 vs {:.4}: {}), useful {:.3e} MAC/s",
                format_ops(model.peak_ops() * measured_util),
                format_ops(est.sustained_raw_ops),
                est.utilization,
                if in_env { "OK" } else { "MISS" },
                est.useful_macs as f64 / t.median,
            );
            rec.record(
                BenchRecord::new(
                    format!("coord.d{density}.shards{shards}.measured_utilization"),
                    measured_util,
                    "ratio",
                )
                .tol(1e-9),
            );
            rec.record(
                BenchRecord::new(
                    format!("coord.d{density}.shards{shards}.predicted_utilization"),
                    est.utilization,
                    "ratio",
                )
                .tol(1e-9),
            );
        }
    }

    // ---- steady-state sparse ALS iteration: plan cache @ 4 shards ----
    // Iterations 2..N of sparse CP-ALS keep the slice maps and quantized
    // fiber codes; only the stored factor images and CP2 scale vectors
    // are requantized in place before each distributed execution.
    common::section("AB-SPARSE: steady-state spALS iteration @ 4 shards (plan cache)");
    let nnz = (total2 as f64 * 0.01) as usize;
    let x = CooTensor::random(&shape2, nnz, &mut rng);
    let mut pool = Coordinator::spawn(CoordinatorConfig::new(4), |_| {
        Ok(CpuTileExecutor::paper())
    })
    .unwrap();
    let t_cold = rec.timed("cold: plan + execute", 1, 3, || {
        let plan = sparse_planner.plan(&x, &factors2, 0).unwrap();
        pool.execute_plan(&plan).unwrap();
    });
    let mut plan = sparse_planner.plan(&x, &factors2, 0).unwrap();
    let t_warm = rec.timed("steady: replan_into + execute", 1, 3, || {
        sparse_planner.replan_into(&factors2, 0, &mut plan).unwrap();
        pool.execute_plan(&plan).unwrap();
    });
    println!(
        "  -> steady-state spALS-iteration speedup: {:.2}x",
        t_cold.median / t_warm.median
    );

    rec.finish();
}
