//! AB-PREC — ablation over operand precision: (a) accuracy of the
//! quantized MTTKRP vs word bits, (b) the peak-throughput trade-off (fewer
//! bits per word → more words per row → more parallel MACs).

#[path = "common/mod.rs"]
mod common;

use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::psram::ArrayGeometry;
use psram_imc::tensor::Matrix;
use psram_imc::util::fixed::quantize_sym;
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

/// Quantized matmul at arbitrary bit width (f64 integer emulation — the
/// functional array models 8-bit; this isolates the numeric effect).
fn quant_matmul_bits(a: &Matrix, b: &Matrix, bits: u32) -> Matrix {
    let (qa, sa) = quantize_sym(a.data(), bits);
    let (qb, sb) = quantize_sym(b.data(), bits);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let x = qa[i * k + p] as i64;
            if x == 0 {
                continue;
            }
            for j in 0..n {
                let v = out.get(i, j) + (x * qb[p * n + j] as i64) as f32 * sa * sb;
                out.set(i, j, v);
            }
        }
    }
    out
}

fn main() {
    common::section("AB-PREC: accuracy of quantized MTTKRP tile vs word bits");
    let mut rng = Prng::new(9);
    let a = Matrix::randn(52, 256, &mut rng);
    let b = Matrix::randn(256, 32, &mut rng);
    let exact = a.matmul(&b).unwrap();
    let exact_norm = exact.fro_norm();
    println!("{:>6} | {:>14} | {:>12}", "bits", "rel RMSE", "SNR (dB)");
    let mut prev = f64::INFINITY;
    for &bits in &[4u32, 6, 8, 10, 12] {
        let approx = quant_matmul_bits(&a, &b, bits);
        let err: f64 = exact
            .data()
            .iter()
            .zip(approx.data())
            .map(|(e, q)| ((e - q) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let rel = err / exact_norm;
        let snr_db = -20.0 * rel.log10();
        println!("{bits:>6} | {rel:>14.6e} | {snr_db:>12.1}");
        assert!(rel < prev, "more bits must not hurt accuracy");
        prev = rel;
    }

    common::section("AB-PREC: model — peak throughput vs word bits (256x256 bits)");
    println!("{:>6} | {:>10} | {:>16} | {:>16}", "bits", "words/row", "peak", "sustained");
    for &bits in &[4u32, 8, 16] {
        let geom = ArrayGeometry::new(256, 256, bits).unwrap();
        let mut m = PerfModel::paper();
        m.geom = geom;
        let est = m.predict(&Workload::paper_large()).unwrap();
        println!(
            "{bits:>6} | {:>10} | {:>16} | {:>16}",
            geom.words_per_row(),
            format_ops(m.peak_ops()),
            format_ops(est.sustained_raw_ops)
        );
    }
    println!("(halving precision doubles words/row and peak ops — the paper's 8-bit");
    println!(" point trades accuracy for the 17 PetaOps headline)");
}
