//! ENGINE — the zero-allocation execution hot loop.
//!
//! Four sections, all on the exact integer path (the simulator's
//! wall-clock, not the modeled device):
//!
//! 1. one compute cycle: allocating `compute_cycle` vs scratch-reusing
//!    `compute_cycle_into` vs the batched `compute_block_into` — simulator
//!    MACs/s on the paper tile;
//! 2. dense steady-state: `execute_plan_into` with a warm `PlanScratch`
//!    over a cached plan (the per-iteration CP-ALS path);
//! 3. sparse steady-state: same over a slice-wise plan;
//! 4. planning: cold `plan_unfolded` / `plan` vs in-place `replan_into` —
//!    the plan-shape cache's per-iteration saving.
//!
//! `-- --json out.json` mirrors every timing row plus the steady-state
//! cycle/MAC censuses into a telemetry report.

#[path = "common/mod.rs"]
mod common;

use psram_imc::compute::ComputeEngine;
use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::mttkrp::plan::{
    execute_plan_into, DensePlanner, PlanScratch, SparseSlicePlanner,
};
use psram_imc::mttkrp::MttkrpStats;
use psram_imc::psram::PsramArray;
use psram_imc::telemetry::{BenchRecord, Direction};
use psram_imc::tensor::{CooTensor, Matrix};
use psram_imc::util::prng::Prng;

fn main() {
    let mut rec = common::Recorder::from_args("bench_engine_hot_loop");
    let mut rng = Prng::new(7);

    // ---- 1. single-cycle paths on the paper tile (52×256×32) ----
    common::section("ENGINE: one compute cycle, 52x256x32 (exact path)");
    let img: Vec<i8> = (0..256 * 32).map(|_| rng.next_i8()).collect();
    let u: Vec<u8> = (0..52 * 256).map(|_| rng.next_u8()).collect();
    let macs_per_cycle = (256 * 32 * 52) as f64;

    let mut eng = ComputeEngine::ideal();
    let mut array = PsramArray::paper();
    array.write_image(&img).unwrap();
    let t = rec.timed("compute_cycle (allocating)", 50, 400, || {
        eng.compute_cycle(&mut array, &u, 52).unwrap();
    });
    println!("  -> {:.3e} simulated MAC/s", macs_per_cycle / t.median);

    let mut out = vec![0i32; 52 * 32];
    let t = rec.timed("compute_cycle_into (scratch)", 50, 400, || {
        eng.compute_cycle_into(&mut array, &u, 52, &mut out).unwrap();
    });
    println!("  -> {:.3e} simulated MAC/s", macs_per_cycle / t.median);

    // A block of 8 cycles: one ledger/energy charge instead of eight.
    let block_u: Vec<u8> = (0..8 * 52 * 256).map(|_| rng.next_u8()).collect();
    let lane_counts = [52usize; 8];
    let mut block_out = vec![0i32; 8 * 52 * 32];
    let t = rec.timed("compute_block_into (8 cycles)", 10, 100, || {
        eng.compute_block_into(&mut array, &block_u, &lane_counts, &mut block_out)
            .unwrap();
    });
    println!("  -> {:.3e} simulated MAC/s", 8.0 * macs_per_cycle / t.median);

    // Direct blocked-kernel rate (no engine dispatch, no ledger): the
    // register-tiled i8×i8→i32 inner loop on the full 52×256×32 tile.
    let image_i32: Vec<i32> = img.iter().map(|&v| v as i32).collect();
    let mut ker_out = vec![0i32; 52 * 32];
    let t = rec.timed("quant_matmul_i32_into (kernel only)", 50, 400, || {
        psram_imc::util::fixed::quant_matmul_i32_into(
            &u, &image_i32, 52, 256, 32, &mut ker_out,
        );
    });
    println!(
        "  -> {:.3e} kernel MAC/s ({:.2} GMAC/s)",
        macs_per_cycle / t.median,
        macs_per_cycle / t.median / 1e9
    );
    rec.record(
        BenchRecord::new("kernel.gmac_per_s", macs_per_cycle / t.median / 1e9, "GMAC/s")
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
    );

    // ---- 2. dense steady state: warm scratch, cached plan ----
    common::section("ENGINE: dense execute_plan_into steady state (520x2048x64)");
    let unf = Matrix::randn(520, 2048, &mut rng);
    let krp = Matrix::randn(2048, 64, &mut rng);
    let planner = DensePlanner::new(256, 32, 52);
    let mut dense_plan = planner.plan_unfolded(&unf, &krp).unwrap();
    let mut exec = CpuTileExecutor::paper();
    let mut scratch = PlanScratch::default();
    let mut dense_out = Matrix::zeros(520, 64);
    let mut stats = MttkrpStats::default();
    execute_plan_into(&mut exec, &dense_plan, &mut scratch, &mut stats, &mut dense_out)
        .unwrap(); // warm-up: grows every scratch buffer
    let raw_macs = {
        let mut s = MttkrpStats::default();
        execute_plan_into(&mut exec, &dense_plan, &mut scratch, &mut s, &mut dense_out)
            .unwrap();
        rec.record(BenchRecord::new("dense.compute_cycles", s.compute_cycles as f64, "cycles"));
        rec.record(BenchRecord::new("dense.write_cycles", s.write_cycles as f64, "cycles"));
        rec.record(BenchRecord::new("dense.raw_macs", s.raw_macs as f64, "MACs"));
        rec.record(BenchRecord::new("dense.useful_macs", s.useful_macs as f64, "MACs"));
        s.raw_macs as f64
    };
    let t = rec.timed("execute_plan_into dense", 1, 5, || {
        let mut s = MttkrpStats::default();
        execute_plan_into(&mut exec, &dense_plan, &mut scratch, &mut s, &mut dense_out)
            .unwrap();
    });
    println!(
        "  -> {:.3e} simulated raw MAC/s (zero allocations per cycle)",
        raw_macs / t.median
    );
    rec.record(
        BenchRecord::new("dense.simulated_raw_mac_per_s", raw_macs / t.median, "MAC/s")
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
    );
    let t_untuned = t.median;

    // Autotuned executor: geometry-driven chunking + intra-shard striping.
    // The census is bit-identical by contract (tests/intra_parallel.rs
    // pins it), so only the wall-clock rate is recorded.
    let tuned = psram_imc::tune::auto_tune(256, 32, 52, 1);
    let mut texec = CpuTileExecutor::paper().with_tuning(&tuned);
    let mut tscratch = PlanScratch::default();
    {
        let mut s = MttkrpStats::default();
        execute_plan_into(&mut texec, &dense_plan, &mut tscratch, &mut s, &mut dense_out)
            .unwrap(); // warm-up: grows the tuned-size scratch
    }
    let t = rec.timed(
        &format!(
            "execute_plan_into dense tuned (bc={}, workers={})",
            tuned.block_cycles, tuned.intra_workers
        ),
        1,
        5,
        || {
            let mut s = MttkrpStats::default();
            execute_plan_into(
                &mut texec,
                &dense_plan,
                &mut tscratch,
                &mut s,
                &mut dense_out,
            )
            .unwrap();
        },
    );
    println!(
        "  -> {:.3e} simulated raw MAC/s tuned ({:.2}x vs untuned)",
        raw_macs / t.median,
        t_untuned / t.median
    );
    rec.record(
        BenchRecord::new("dense.tuned_raw_mac_per_s", raw_macs / t.median, "MAC/s")
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
    );
    rec.record(
        BenchRecord::new("dense.tuned_speedup", t_untuned / t.median, "ratio")
            .better(Direction::Higher)
            .wall_clock(),
    );

    // ---- 3. sparse steady state ----
    common::section("ENGINE: sparse execute_plan_into steady state (64x2048x16, 1% dense)");
    let shape = [64usize, 2048, 16];
    let nnz = (shape.iter().product::<usize>() as f64 * 0.01) as usize;
    let coo = CooTensor::random(&shape, nnz, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 32, &mut rng)).collect();
    let sparse_planner = SparseSlicePlanner::new(256, 32, 52);
    let mut sparse_plan = sparse_planner.plan(&coo, &factors, 0).unwrap();
    let mut sparse_out = Matrix::zeros(64, 32);
    let sparse_macs = {
        let mut s = MttkrpStats::default();
        execute_plan_into(&mut exec, &sparse_plan, &mut scratch, &mut s, &mut sparse_out)
            .unwrap();
        rec.record(BenchRecord::new("sparse.compute_cycles", s.compute_cycles as f64, "cycles"));
        rec.record(BenchRecord::new("sparse.write_cycles", s.write_cycles as f64, "cycles"));
        rec.record(BenchRecord::new("sparse.raw_macs", s.raw_macs as f64, "MACs"));
        rec.record(BenchRecord::new("sparse.useful_macs", s.useful_macs as f64, "MACs"));
        (s.raw_macs as f64, s.useful_macs as f64)
    };
    let t = rec.timed("execute_plan_into sparse", 1, 5, || {
        let mut s = MttkrpStats::default();
        execute_plan_into(&mut exec, &sparse_plan, &mut scratch, &mut s, &mut sparse_out)
            .unwrap();
    });
    println!(
        "  -> {:.3e} raw / {:.3e} useful simulated MAC/s",
        sparse_macs.0 / t.median,
        sparse_macs.1 / t.median
    );

    // ---- 4. planning: cold plan vs in-place replan ----
    common::section("ENGINE: plan-shape cache — cold plan vs replan_into");
    let t_cold = rec.timed("dense plan_unfolded (cold)", 1, 5, || {
        planner.plan_unfolded(&unf, &krp).unwrap();
    });
    let t_warm = rec.timed("dense replan_into (KRP only)", 1, 5, || {
        planner.replan_into(None, &krp, &mut dense_plan).unwrap();
    });
    println!(
        "  -> per-iteration planning speedup: {:.2}x",
        t_cold.median / t_warm.median
    );

    let t_cold = rec.timed("sparse plan (cold)", 1, 5, || {
        sparse_planner.plan(&coo, &factors, 0).unwrap();
    });
    let t_warm = rec.timed("sparse replan_into (stored only)", 1, 5, || {
        sparse_planner.replan_into(&factors, 0, &mut sparse_plan).unwrap();
    });
    println!(
        "  -> per-iteration planning speedup: {:.2}x",
        t_cold.median / t_warm.median
    );

    rec.finish();
}
