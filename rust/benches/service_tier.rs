//! SERVICE — the admission-controlled tier: virtual-clock simulator
//! throughput (how many offered jobs per wall-second the deterministic
//! harness replays), the pinned hand-traced scenario the telemetry gate
//! rides on, and the live scheduler's end-to-end serve rate over
//! single-array session pools.

#[path = "common/mod.rs"]
mod common;

use psram_imc::perfmodel::PerfModel;
use psram_imc::service::{
    pinned_report, JobSpec, PoolSpec, Scheduler, ServiceConfig, TenantId, TenantSpec,
    TrafficConfig,
};
use psram_imc::telemetry::{BenchRecord, Direction};

fn main() {
    let mut rec = common::Recorder::from_args("bench_service_tier");
    let model = PerfModel::paper();

    common::section("SERVICE: virtual-clock simulator throughput (paper mix, 3 tenants)");
    for &jobs in &[40usize, 120, 360] {
        let mut cfg = TrafficConfig::paper(4242);
        for load in &mut cfg.tenants {
            load.jobs = jobs;
        }
        let total = jobs * cfg.tenants.len();
        let mut last = None;
        let t = rec.timed(&format!("simulate {total} arrivals"), 1, 5, || {
            last = Some(cfg.run(&model).unwrap());
        });
        let r = last.unwrap();
        println!(
            "  -> {} completed, utilization {:.3}, {:.0} sim jobs per wall-second",
            r.counters.completed,
            r.utilization,
            total as f64 / t.median
        );
        rec.record(
            BenchRecord::new(
                format!("sim.jobs{total}.jobs_per_s"),
                total as f64 / t.median,
                "jobs/s",
            )
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
        );
        // The mid-size scenario's deterministic observables: same seed,
        // same bits, on any machine.
        if total == 360 {
            rec.record(
                BenchRecord::new(format!("sim.jobs{total}.wait_p95_cycles"), r.wait_p95, "cycles")
                    .tol(1e-9),
            );
            rec.record(
                BenchRecord::new(format!("sim.jobs{total}.utilization"), r.utilization, "ratio")
                    .tol(1e-9),
            );
        }
    }

    common::section("SERVICE: pinned hand-traced scenario (the telemetry gate)");
    let p = pinned_report();
    print!("{p}");
    rec.record(BenchRecord::new("pinned.completed", p.counters.completed as f64, "jobs"));
    rec.record(BenchRecord::new("pinned.wait_p95_cycles", p.wait_p95, "cycles").tol(1e-9));

    common::section("SERVICE: live scheduler serve rate (single-array pools)");
    for &pools in &[1usize, 2] {
        let cfg = ServiceConfig {
            queue_bound: 64,
            tenants: (0..3u32)
                .map(|i| (TenantId(i), TenantSpec { weight: 3 - i, quota: usize::MAX }))
                .collect(),
            default_tenant: TenantSpec::default(),
        };
        let n = 18usize;
        let t = rec.timed(&format!("serve {n} jobs, {pools} pool(s)"), 1, 3, || {
            let specs: Vec<PoolSpec> = (0..pools).map(|_| PoolSpec::single()).collect();
            let sched = Scheduler::new(&cfg, &specs, model.clone()).unwrap();
            // Submit paused so the stride order, not submission racing,
            // decides dispatch; resume, then drain every handle.
            sched.pause();
            let handles: Vec<_> = (0..n)
                .map(|i| {
                    let spec = JobSpec::DenseMttkrp {
                        shape: [32, 16, 8],
                        rank: 4,
                        mode: i % 3,
                        seed: i as u64,
                    };
                    sched.submit(TenantId((i % 3) as u32), spec).unwrap()
                })
                .collect();
            sched.resume();
            for h in handles {
                assert!(h.wait().is_done());
            }
        });
        println!("  -> {:.0} served jobs/s end to end", n as f64 / t.median);
        rec.record(
            BenchRecord::new(
                format!("serve.pools{pools}.jobs_per_s"),
                n as f64 / t.median,
                "jobs/s",
            )
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
        );
    }

    rec.finish();
}
