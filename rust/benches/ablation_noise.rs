//! AB-NOISE — ablation: CP-ALS decomposition quality vs optical/detector
//! noise (sigma in ideal-LSB units of the analog column sum), using the
//! ground-truth (brute-force) fit.

#[path = "common/mod.rs"]
mod common;

use psram_imc::compute::ComputeEngine;
use psram_imc::cpd::{brute_force_fit, AlsConfig, CpAls, PsramBackend};
use psram_imc::device::{DeviceParams, LinkBudget, NoiseModel, Photodiode};
use psram_imc::mttkrp::pipeline::AnalogTileExecutor;
use psram_imc::psram::PsramArray;
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;

fn main() {
    common::section("AB-NOISE: verified CP-ALS fit vs detector noise sigma");
    let mut rng = Prng::new(77);
    let shape = [24usize, 20, 16];
    let truth: Vec<Matrix> = shape.iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
    let x = DenseTensor::from_cp_factors(&truth, 0.0, &mut rng).unwrap();

    // Where the physical link budget sits:
    let phys_sigma = LinkBudget::default().noise_sigma_lsb(
        &Photodiode::default(),
        20e9,
        256.0 * 255.0,
    );
    println!("physical link-budget sigma at full 256-row swing: {phys_sigma:.2} LSB\n");

    println!("{:>12} | {:>12} | {:>10}", "sigma (LSB)", "fit (true)", "starts");
    let mut fits = Vec::new();
    for &sigma in &[0.0f64, 50.0, 1e3, 1e4, 1e5, 1e6, 4e6] {
        // best of 3 ALS starts (ALS is init-sensitive; standard practice)
        let mut best = f64::NEG_INFINITY;
        for seed in [5u64, 6, 7] {
            let engine = ComputeEngine::new(
                DeviceParams::default(),
                NoiseModel::gaussian(sigma, 1234),
            );
            let exec = AnalogTileExecutor::new(engine, PsramArray::paper());
            let mut backend = PsramBackend::new(&x, exec);
            let res = CpAls::new(AlsConfig { rank: 3, max_iters: 20, tol: 1e-7, seed })
                .run_backend(&mut backend)
                .unwrap();
            best = best.max(brute_force_fit(&x, &res.factors, &res.lambda));
        }
        println!("{sigma:>12.1e} | {best:>12.6} | {:>10}", 3);
        fits.push(best);
    }
    assert!(fits[0] > 0.95, "clean fit should be high");
    assert!(
        fits[fits.len() - 1] < fits[0],
        "extreme noise must degrade the decomposition"
    );
    println!("\n(shape: flat plateau until sigma ≈ 1e4 LSB — ALS absorbs zero-mean");
    println!(" detector noise — then collapse as per-readout SNR → 0; the physical");
    println!(" operating point sits ~4 orders of magnitude inside the plateau)");
}
