//! BASE-CPU — digital baselines vs the pSRAM paths on one MTTKRP
//! (96×80×72 tensor, rank 16, mode 0): exact f32 CPU, quantized CPU
//! integer executor, device-faithful analog simulator, and the AOT Pallas
//! kernel via PJRT (plus the dense-f32 PJRT baseline artifact).

#[path = "common/mod.rs"]
mod common;

use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor, PsramPipeline};
use psram_imc::mttkrp::reference::dense_mttkrp;
use psram_imc::runtime::{PjrtRuntime, PjrtTileExecutor};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;

fn main() {
    let mut rng = Prng::new(11);
    let shape = [96usize, 80, 72];
    let rank = 16;
    let x = DenseTensor::randn(&shape, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, rank, &mut rng)).collect();
    let macs = (shape[0] * shape[1] * shape[2] * rank) as f64;

    common::section("digital baselines vs pSRAM paths — MTTKRP 96x80x72 r16");
    let t = common::bench("cpu f32 dense_mttkrp (exact baseline)", 1, 5, || {
        dense_mttkrp(&x, &factors, 0).unwrap();
    });
    println!("  -> {:.3e} MAC/s", macs / t);

    let t = common::bench("quantized pipeline (cpu int executor)", 1, 5, || {
        let mut e = CpuTileExecutor::paper();
        PsramPipeline::new(&mut e).mttkrp(&x, &factors, 0).unwrap();
    });
    println!("  -> {:.3e} MAC/s", macs / t);

    let t = common::bench("quantized pipeline (analog simulator)", 1, 3, || {
        let mut e = AnalogTileExecutor::ideal();
        PsramPipeline::new(&mut e).mttkrp(&x, &factors, 0).unwrap();
    });
    println!("  -> {:.3e} MAC/s", macs / t);

    match PjrtTileExecutor::paper() {
        Ok(_) => {
            let t = common::bench("quantized pipeline (PJRT pallas kernel)", 1, 3, || {
                let mut e = PjrtTileExecutor::paper().unwrap();
                PsramPipeline::new(&mut e).mttkrp(&x, &factors, 0).unwrap();
            });
            println!("  -> {:.3e} MAC/s (includes executable-cache build)", macs / t);

            // Steady-state PJRT: reuse one compiled executor.
            let mut e = PjrtTileExecutor::paper().unwrap();
            let t = common::bench("   same, warm executable cache", 1, 3, || {
                PsramPipeline::new(&mut e).mttkrp(&x, &factors, 0).unwrap();
            });
            println!("  -> {:.3e} MAC/s", macs / t);
        }
        Err(e) => println!("PJRT paths skipped (run `make artifacts`): {e}"),
    }

    common::section("PJRT dense-f32 baseline artifact (mttkrp_f32_64x48x40_r16)");
    match PjrtRuntime::new() {
        Ok(mut rt) => {
            let (i, j, k, r) = (64usize, 48usize, 40usize, 16usize);
            let xs = DenseTensor::randn(&[i, j, k], &mut rng);
            let b = Matrix::randn(j, r, &mut rng);
            let c = Matrix::randn(k, r, &mut rng);
            rt.execute_mttkrp_f32(
                "mttkrp_f32_64x48x40_r16",
                xs.data(),
                b.data(),
                c.data(),
                i,
                j,
                k,
                r,
            )
            .unwrap(); // compile once
            let macs2 = (i * j * k * r) as f64;
            let t = common::bench("pjrt f32 mttkrp block 64x48x40 r16", 2, 10, || {
                rt.execute_mttkrp_f32(
                    "mttkrp_f32_64x48x40_r16",
                    xs.data(),
                    b.data(),
                    c.data(),
                    i,
                    j,
                    k,
                    r,
                )
                .unwrap();
            });
            println!("  -> {:.3e} MAC/s", macs2 / t);
        }
        Err(e) => println!("skipped: {e}"),
    }
}
