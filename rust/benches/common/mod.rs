//! Shared measurement harness for the benches (criterion is unavailable
//! offline; this provides warmup + repetition + median/min/stddev
//! reporting with a stable, grep-friendly output format) plus a
//! [`Recorder`] that mirrors results into a machine-readable
//! `telemetry::BenchReport` when the bench is invoked with
//! `--json <path>` (`cargo bench --bench <name> -- --json out.json`).
#![allow(dead_code)] // each bench uses a subset of these helpers

use psram_imc::telemetry::{capture_env, BenchRecord, BenchReport, Direction};
use std::path::PathBuf;
use std::time::Instant;

/// Summary statistics of one timed section.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Median seconds across the measured repetitions.
    pub median: f64,
    /// Mean seconds.
    pub mean: f64,
    /// Fastest repetition (the least-noise estimate).
    pub min: f64,
    /// Population standard deviation of the repetitions.
    pub std: f64,
    /// Number of measured repetitions the row summarizes.
    pub n: u64,
}

/// Time `f` with `warmup` unmeasured and `reps` measured runs; prints a
/// result row and returns the full statistics (median/mean/min/std and
/// the sample count `n` they were computed over).
pub fn bench_stats<F: FnMut()>(
    name: &str,
    warmup: usize,
    reps: usize,
    mut f: F,
) -> BenchStats {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<f64>() / reps as f64;
    let std = (times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / reps as f64)
        .sqrt();
    println!(
        "bench {name:<42} median {:>12} mean {:>12} ± {:>10} min {:>12} (n={reps})",
        fmt_s(median),
        fmt_s(mean),
        fmt_s(std),
        fmt_s(min),
    );
    BenchStats { median, mean, min, std, n: reps as u64 }
}

/// [`bench_stats`] returning just the median seconds (the historical
/// return; sweep-style benches that only need one scalar use this).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, f: F) -> f64 {
    bench_stats(name, warmup, reps, f).median
}

/// Human-readable seconds.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Mirrors bench results into a [`BenchReport`] written on [`finish`]
/// (`Recorder::finish`) when the bench was invoked with `--json <path>`.
///
/// Records are collected unconditionally (the cost is trivial next to
/// the measurements) so a bench behaves identically with and without the
/// flag; only the final write is conditional.  Duplicate metric names
/// are a bench bug and panic immediately.
pub struct Recorder {
    report: BenchReport,
    path: Option<PathBuf>,
}

impl Recorder {
    /// A recorder for bench `suite`, reading `--json <path>` from the
    /// process arguments (other arguments — e.g. the `--bench` cargo
    /// appends — are ignored).
    pub fn from_args(suite: &str) -> Recorder {
        let mut path = None;
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--json" {
                match args.next() {
                    Some(p) => path = Some(PathBuf::from(p)),
                    None => panic!("--json requires a path argument"),
                }
            }
        }
        Recorder {
            report: BenchReport::new(suite, capture_env(None)),
            path,
        }
    }

    /// Append one record (panics on duplicate names or non-finite
    /// values — both are bench bugs, not runtime conditions).
    pub fn record(&mut self, rec: BenchRecord) {
        let name = rec.name.clone();
        self.report
            .push(rec)
            .unwrap_or_else(|e| panic!("telemetry record {name:?}: {e}"));
    }

    /// Append a wall-clock timing row: the median of `stats` with its
    /// sample count, marked non-gating.
    pub fn wall(&mut self, name: &str, stats: &BenchStats) {
        self.record(
            BenchRecord::new(name, stats.median, "s")
                .better(Direction::Lower)
                .wall_clock()
                .samples(stats.n),
        );
    }

    /// Time a section through [`bench_stats`] *and* mirror it into the
    /// report under `name`, returning the statistics.
    pub fn timed<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        reps: usize,
        f: F,
    ) -> BenchStats {
        let stats = bench_stats(name, warmup, reps, f);
        self.wall(name, &stats);
        stats
    }

    /// Write the report if `--json` was passed; always safe to call last.
    pub fn finish(&self) {
        if let Some(path) = &self.path {
            self.report
                .write_file(path)
                .unwrap_or_else(|e| panic!("telemetry write {path:?}: {e}"));
            println!("\ntelemetry: wrote {} records to {}", self.report.records.len(), path.display());
        }
    }
}
