//! Shared measurement harness for the benches (criterion is unavailable
//! offline; this provides warmup + repetition + median/stddev reporting
//! with a stable, grep-friendly output format).
#![allow(dead_code)] // each bench uses a subset of these helpers

use std::time::Instant;

/// Time `f` with `warmup` unmeasured and `reps` measured runs; prints a
/// result row and returns the median seconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> f64 {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / reps as f64;
    let std = (times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / reps as f64)
        .sqrt();
    println!(
        "bench {name:<42} median {:>12} mean {:>12} ± {:>10} ({reps} reps)",
        fmt_s(median),
        fmt_s(mean),
        fmt_s(std)
    );
    median
}

/// Human-readable seconds.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
