//! TUCKER — the Tucker/HOOI workload on the tile-plan IR.
//!
//! Three sections:
//! 1. TTM shard sweep — one dense TTM plan distributed over 1→16
//!    coordinator shards, wall-clock + device-model sustained throughput
//!    against `PerfModel::predict_plan`: the cycle census is *exact*
//!    (predicted == measured), not an envelope;
//! 2. steady-state HOOI iteration — what a plan-cached HOOI sweep pays
//!    per fixed-stream TTM (image requantization only) vs cold planning
//!    (unfold + transpose + full quantization) every call;
//! 3. end-to-end HOOI — a full decomposition on the 4-shard coordinator
//!    with the reconstruction fit.

#[path = "common/mod.rs"]
mod common;

use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::mttkrp::cache::TtmPlanCache;
use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::mttkrp::plan::TtmPlanner;
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::telemetry::BenchRecord;
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::tucker::{
    tucker_fit, tucker_reconstruct, CoordinatedTtmBackend, TuckerConfig, TuckerHooi,
};
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

fn main() {
    let mut rec = common::Recorder::from_args("bench_tucker_hooi");
    let mut rng = Prng::new(17);

    // One dense TTM: X (4096 x 52 x 40) ×₀ Uᵀ with U [4096, 64] —
    // 16 contraction blocks x 2 rank blocks = 32 images, 40 lane batches
    // per group, so sharding and batching are both exercised.
    let shape = [4096usize, 52, 40];
    let x = DenseTensor::randn(&shape, &mut rng);
    let u = Matrix::randn(4096, 64, &mut rng);
    let planner = TtmPlanner::new(256, 32, 52);
    let plan = planner.plan_ttm(&x, &u, 0).unwrap();
    let workload = Workload::ttm(&shape, 0, 64).unwrap();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    common::section(&format!(
        "TUCKER: sharded TTM {}x{}x{} x0 U^T (rank 64) vs shard count \
         ({cores} core(s) available)",
        shape[0], shape[1], shape[2]
    ));
    if cores == 1 {
        println!("NOTE: single-core machine — parallel speedup is physically impossible;");
        println!("      this bench then measures coordination OVERHEAD (should be ~flat).");
    }

    let mut t1 = 0.0;
    let mut exact = true;
    for &shards in &[1usize, 2, 4, 8, 16] {
        let mut model = PerfModel::paper();
        model.num_arrays = shards;
        let cfg = CoordinatorConfig::from_model(&model, &workload);
        let t = rec.timed(
            &format!("ttm 2080x4096x64 shards={shards:>2}"),
            1,
            3,
            || {
                let mut pool = Coordinator::spawn(cfg.clone(), |_| {
                    Ok(CpuTileExecutor::paper())
                })
                .unwrap();
                pool.execute_plan(&plan).unwrap();
            },
        );
        if shards == 1 {
            t1 = t.median;
        } else {
            println!("  -> speedup vs 1 shard: {:.2}x", t1 / t.median);
        }

        // predict_plan scores a TTM plan exactly like dense MTTKRP: the
        // cycle census must equal the pool's measured metrics bit for bit.
        let est = model.predict_plan(&plan).unwrap();
        let mut pool =
            Coordinator::spawn(cfg, |_| Ok(CpuTileExecutor::paper())).unwrap();
        pool.execute_plan(&plan).unwrap();
        let m = pool.metrics();
        let snap = m.snapshot();
        let ok = est.images == snap[1].1
            && est.compute_cycles == snap[2].1
            && est.reconfig_write_cycles == snap[3].1
            && (est.utilization - m.utilization()).abs() < 1e-12;
        exact &= ok;
        println!(
            "  -> sustained {} measured vs {} predicted \
             (U {:.4}, predicted==measured: {})",
            format_ops(model.peak_ops() * m.utilization()),
            format_ops(est.sustained_raw_ops),
            est.utilization,
            if ok { "EXACT" } else { "MISS" },
        );
        rec.record(BenchRecord::new(
            format!("ttm.shards{shards}.measured_images"),
            snap[1].1 as f64,
            "images",
        ));
        rec.record(BenchRecord::new(
            format!("ttm.shards{shards}.measured_compute_cycles"),
            snap[2].1 as f64,
            "cycles",
        ));
        rec.record(
            BenchRecord::new(
                format!("ttm.shards{shards}.measured_utilization"),
                m.utilization(),
                "ratio",
            )
            .tol(1e-9),
        );
        rec.record(
            BenchRecord::new(
                format!("ttm.shards{shards}.predicted_utilization"),
                est.utilization,
                "ratio",
            )
            .tol(1e-9),
        );
    }
    println!(
        "\nprediction envelope: {}",
        if exact { "cycle-exact at every shard count" } else { "MISSED" }
    );

    common::section("TUCKER: steady-state HOOI iteration @ 4 shards (plan cache)");
    // What a plan-cached HOOI sweep pays for a fixed-stream TTM from
    // iteration 2 on: requantize the stored factor images in place, then
    // execute.  The cold row re-unfolds, re-transposes, and re-quantizes
    // the whole streamed operand every call.
    {
        let mut pool = Coordinator::spawn(CoordinatorConfig::new(4), |_| {
            Ok(CpuTileExecutor::paper())
        })
        .unwrap();
        let t_cold = rec.timed("cold: unfold + plan + execute", 1, 3, || {
            let plan = planner.plan_ttm(&x, &u, 0).unwrap();
            pool.execute_plan(&plan).unwrap();
        });
        let mut cache = TtmPlanCache::new(planner);
        cache.plan_fixed_stream(0, &x, 0, &u).unwrap();
        let t_warm = rec.timed("steady: replan_into + execute", 1, 3, || {
            let plan = cache.plan_fixed_stream(0, &x, 0, &u).unwrap();
            pool.execute_plan(plan).unwrap();
        });
        println!(
            "  -> steady-state HOOI-iteration speedup: {:.2}x",
            t_cold.median / t_warm.median
        );
    }

    common::section("TUCKER: end-to-end HOOI (64x56x48 -> core 8x8x8) @ 4 shards");
    let shape2 = [64usize, 56, 48];
    let ranks = vec![8usize, 8, 8];
    let core = DenseTensor::randn(&ranks, &mut rng);
    let truth: Vec<Matrix> = shape2
        .iter()
        .zip(&ranks)
        .map(|(&d, &r)| Matrix::randn(d, r, &mut rng))
        .collect();
    let x2 = tucker_reconstruct(&core, &truth).unwrap();
    let hooi = TuckerHooi::new(TuckerConfig {
        ranks: ranks.clone(),
        max_iters: 10,
        tol: 1e-6,
    });
    let mut fit = 0.0;
    rec.timed("hooi 10 sweeps (coordinator x4)", 1, 3, || {
        let pool =
            Coordinator::with_workers(4, |_| Ok(CpuTileExecutor::paper())).unwrap();
        let mut backend = CoordinatedTtmBackend::new(pool);
        let res = hooi.run_backend(&x2, &mut backend).unwrap();
        fit = tucker_fit(&x2, &res.core, &res.factors).unwrap();
    });
    println!("  -> reconstruction fit {fit:.6}");
    // 1e-3, not tighter: the fit goes through ln/sin_cos in randn and a
    // full HOOI sweep, so the last few ulps vary across libm versions.
    rec.record(BenchRecord::new("hooi.reconstruction_fit", fit, "fit").tol(1e-3));

    rec.finish();
}
