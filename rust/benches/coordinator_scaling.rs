//! COORD — L3 coordinator scaling: the sharded batched pool over 1→16
//! simulated arrays on one distributed MTTKRP.
//!
//! Three sections:
//! 1. shard sweep — wall-clock + *device-model* sustained throughput
//!    (peak × measured utilisation from the cycle metrics) against the
//!    `perfmodel` prediction for the same array count: the measured point
//!    must land inside the model's prediction envelope;
//! 2. batching — write-amortization: images per batch vs wall-clock;
//! 3. work stealing — a skewed workload (all batches on one shard) with
//!    stealing on vs off.

#[path = "common/mod.rs"]
mod common;

use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::mttkrp::plan::DensePlanner;
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::session::{Engine, JobId, Kernel, PsramSession};
use psram_imc::telemetry::{BenchRecord, Direction};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;
use std::sync::atomic::Ordering;

/// Tolerance of the model-vs-measured utilisation comparison.  The model
/// distributes images as ceil(images / arrays); the pool shards by
/// contraction block, which matches exactly when k_blocks % shards == 0
/// (as here) and differs by at most one image per array otherwise.
const ENVELOPE: f64 = 0.02;

fn main() {
    let mut rec = common::Recorder::from_args("bench_coordinator_scaling");
    let mut rng = Prng::new(13);
    // 16 K-blocks x 4 R-blocks = 64 images, 20 lane batches each.
    let (i_dim, k_dim, r_dim) = (1040usize, 4096usize, 128usize);
    let unf = Matrix::randn(i_dim, k_dim, &mut rng);
    let krp = Matrix::randn(k_dim, r_dim, &mut rng);
    let workload = Workload {
        i_rows: i_dim as u64,
        k_contraction: k_dim as u64,
        rank: r_dim as u64,
    };

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    common::section(&format!(
        "COORD: sharded MTTKRP {i_dim}x{k_dim}x{r_dim} vs shard count \
         ({cores} core(s) available)"
    ));
    if cores == 1 {
        println!("NOTE: single-core machine — parallel speedup is physically impossible;");
        println!("      this bench then measures coordination OVERHEAD (should be ~flat).");
    }

    let mut t1 = 0.0;
    let mut envelope_ok = true;
    for &shards in &[1usize, 2, 4, 8, 16] {
        let mut model = PerfModel::paper();
        model.num_arrays = shards;
        let cfg = CoordinatorConfig::from_model(&model, &workload);
        let t = rec.timed(
            &format!("mttkrp {i_dim}x{k_dim}x{r_dim} shards={shards:>2}"),
            1,
            3,
            || {
                let mut pool = Coordinator::spawn(cfg.clone(), |_| {
                    Ok(CpuTileExecutor::paper())
                })
                .unwrap();
                pool.mttkrp_unfolded(&unf, &krp).unwrap();
            },
        );
        if shards == 1 {
            t1 = t.median;
        } else {
            println!("  -> speedup vs 1 shard: {:.2}x", t1 / t.median);
        }

        // Device-model throughput from the cycle metrics of one fresh run,
        // against the perfmodel prediction for the same array count.
        let mut pool =
            Coordinator::spawn(cfg, |_| Ok(CpuTileExecutor::paper())).unwrap();
        pool.mttkrp_unfolded(&unf, &krp).unwrap();
        let m = pool.metrics();
        let measured_util = m.utilization();
        let measured_sustained = model.peak_ops() * measured_util;
        let est = model.predict(&workload).unwrap();
        let in_env = (measured_util - est.utilization).abs() <= ENVELOPE;
        envelope_ok &= in_env;
        println!(
            "  -> sustained {} measured vs {} predicted \
             (U {measured_util:.4} vs {:.4}, envelope +/-{ENVELOPE}: {})",
            format_ops(measured_sustained),
            format_ops(est.sustained_raw_ops),
            est.utilization,
            if in_env { "OK" } else { "MISS" },
        );
        println!(
            "  -> {} batches, {} images, {} steals",
            m.batches.load(Ordering::Relaxed),
            m.images.load(Ordering::Relaxed),
            m.steals.load(Ordering::Relaxed)
        );
        rec.record(
            BenchRecord::new(
                format!("shards{shards}.measured_utilization"),
                measured_util,
                "ratio",
            )
            .tol(1e-9),
        );
        rec.record(
            BenchRecord::new(
                format!("shards{shards}.predicted_utilization"),
                est.utilization,
                "ratio",
            )
            .tol(1e-9),
        );
        rec.record(
            BenchRecord::new(
                format!("shards{shards}.measured_sustained_ops"),
                measured_sustained,
                "ops/s",
            )
            .better(Direction::Higher)
            .tol(1e-9),
        );
        rec.record(BenchRecord::new(
            format!("shards{shards}.measured_images"),
            m.images.load(Ordering::Relaxed) as f64,
            "images",
        ));
        rec.record(
            BenchRecord::new(
                format!("shards{shards}.images_per_s"),
                m.images.load(Ordering::Relaxed) as f64 / t.median,
                "images/s",
            )
            .better(Direction::Higher)
            .wall_clock()
            .samples(t.n),
        );
    }
    println!(
        "\nprediction envelope: {}",
        if envelope_ok { "all shard counts within the model envelope" } else { "MISSED" }
    );

    common::section("COORD: autotuned executors (chunking + intra-shard striping) @ 4 shards");
    // Same workload, same pool shape — the only delta is per-worker
    // tuning.  The census (and the f32 result) is bit-identical either
    // way; the stripe width shares host cores across the 4 shards.
    {
        let tuned = psram_imc::tune::auto_tune(256, 32, 52, 4);
        let t_untuned = rec.timed("mttkrp 4 shards untuned", 1, 3, || {
            let mut pool = Coordinator::spawn(
                CoordinatorConfig::new(4),
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            pool.mttkrp_unfolded(&unf, &krp).unwrap();
        });
        let t_tuned = rec.timed(
            &format!(
                "mttkrp 4 shards tuned (bc={}, workers={})",
                tuned.block_cycles, tuned.intra_workers
            ),
            1,
            3,
            || {
                let mut pool = Coordinator::spawn(
                    CoordinatorConfig::new(4),
                    |_| Ok(CpuTileExecutor::paper().with_tuning(&tuned)),
                )
                .unwrap();
                pool.mttkrp_unfolded(&unf, &krp).unwrap();
            },
        );
        println!(
            "  -> tuned speedup @ 4 shards: {:.2}x",
            t_untuned.median / t_tuned.median
        );
        rec.record(
            BenchRecord::new(
                "tuned.shards4.speedup",
                t_untuned.median / t_tuned.median,
                "ratio",
            )
            .better(Direction::Higher)
            .wall_clock(),
        );
    }

    common::section("COORD: write amortization — images per batch @ 4 shards");
    for &batch in &[1usize, 2, 4] {
        rec.timed(&format!("mttkrp batch_size={batch}"), 1, 3, || {
            let mut pool = Coordinator::spawn(
                CoordinatorConfig { batch_size: batch, ..CoordinatorConfig::new(4) },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            pool.mttkrp_unfolded(&unf, &krp).unwrap();
        });
    }

    common::section("COORD: steady-state ALS iteration @ 4 shards (plan cache)");
    // What CP-ALS actually pays per iteration 2..N: the pool persists, the
    // plan's shape + streamed codes are cached, and only the KRP images
    // are requantized in place before the distributed execution.  The
    // cold row replans (and re-quantizes the whole operand) every call —
    // the pre-plan-cache behaviour.
    {
        let planner = DensePlanner::new(256, 32, 52);
        let mut pool = Coordinator::spawn(CoordinatorConfig::new(4), |_| {
            Ok(CpuTileExecutor::paper())
        })
        .unwrap();
        let t_cold = rec.timed("cold: plan + execute", 1, 3, || {
            let plan = planner.plan_unfolded(&unf, &krp).unwrap();
            pool.execute_plan(&plan).unwrap();
        });
        let mut plan = planner.plan_unfolded(&unf, &krp).unwrap();
        let t_warm = rec.timed("steady: replan_into + execute", 1, 3, || {
            planner.replan_into(None, &krp, &mut plan).unwrap();
            pool.execute_plan(&plan).unwrap();
        });
        println!(
            "  -> steady-state ALS-iteration speedup: {:.2}x",
            t_cold.median / t_warm.median
        );
    }

    common::section("COORD: multi-tenant jobs sharing one pool (PsramSession)");
    // N concurrent decomposition jobs share ONE coordinated session: each
    // thread owns a SessionJob handle, submits dense MTTKRPs on its own
    // tensor, and is metered separately.  Requests time-share the device
    // (the leader executes one plan at a time; tenants' planning overlaps
    // execution, their batches do not co-run), so per-job *device-model*
    // sustained throughput (peak x the job's attributed utilisation) is
    // reported against the single-job envelope the perfmodel predicts —
    // matching figures confirm sharing costs no cycles, only wall-clock
    // time-slicing.
    {
        let (i_dim, j_dim, k_dim, r_dim) = (1040usize, 64, 32, 128);
        let per_job_workload = Workload {
            i_rows: i_dim as u64,
            k_contraction: (j_dim * k_dim) as u64,
            rank: r_dim as u64,
        };
        let reps = 3usize; // kernels per job (mode-0 MTTKRPs)
        for &shards in &[1usize, 2, 4, 8, 16] {
            for &jobs in &[2usize, 4] {
                let mut model = PerfModel::paper();
                model.num_arrays = shards;
                let single_env = model.predict(&per_job_workload).unwrap();

                let session = PsramSession::builder()
                    .engine(Engine::Coordinated { shards })
                    .build()
                    .unwrap();
                // One tensor + factor set per job (identical shapes, so
                // the jobs contend for the same shard pattern; distinct
                // data, so per-job plan namespaces are load-bearing).
                let mut rng = Prng::new(1000 + shards as u64);
                let tensors: Vec<DenseTensor> = (0..jobs)
                    .map(|_| DenseTensor::randn(&[i_dim, j_dim, k_dim], &mut rng))
                    .collect();
                let factor_sets: Vec<Vec<Matrix>> = (0..jobs)
                    .map(|_| {
                        [i_dim, j_dim, k_dim]
                            .iter()
                            .map(|&d| Matrix::randn(d, r_dim, &mut rng))
                            .collect()
                    })
                    .collect();

                let t0 = std::time::Instant::now();
                std::thread::scope(|scope| {
                    for j in 0..jobs {
                        let job = session.job(JobId(j as u64 + 1));
                        let x = &tensors[j];
                        let factors = &factor_sets[j];
                        scope.spawn(move || {
                            for _ in 0..reps {
                                job.run(Kernel::DenseMttkrp { x, factors, mode: 0 })
                                    .unwrap();
                            }
                        });
                    }
                });
                let wall = t0.elapsed().as_secs_f64();

                // Device-model throughput per job from its attributed
                // cycles; every job ran the same workload, so report the
                // min/max spread across tenants.
                let mut per_job = Vec::new();
                for j in 0..jobs {
                    let snap = session.job_metrics(JobId(j as u64 + 1));
                    per_job.push(model.peak_ops() * snap.utilization());
                }
                let lo = per_job.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = per_job.iter().cloned().fold(0.0f64, f64::max);
                println!(
                    "bench multi-tenant shards={shards:>2} jobs={jobs} \
                     wall {wall:.3}s  per-job sustained {} .. {} \
                     (single-job envelope {})",
                    format_ops(lo),
                    format_ops(hi),
                    format_ops(single_env.sustained_raw_ops),
                );
                rec.record(
                    BenchRecord::new(
                        format!("multi_tenant.shards{shards}.jobs{jobs}.wall_s"),
                        wall,
                        "s",
                    )
                    .better(Direction::Lower)
                    .wall_clock(),
                );
            }
        }
    }

    common::section("COORD: work stealing on a single-shard-skewed workload @ 4 shards");
    // K fits one contraction block -> every batch lands on shard 0; only
    // stealing lets the other three workers contribute.
    let skew_unf = Matrix::randn(1040, 256, &mut rng);
    let skew_krp = Matrix::randn(256, 512, &mut rng);
    for &steal in &[false, true] {
        let t = rec.timed(&format!("skewed mttkrp steal={steal}"), 1, 3, || {
            let mut pool = Coordinator::spawn(
                CoordinatorConfig {
                    batch_size: 1,
                    steal,
                    ..CoordinatorConfig::new(4)
                },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            pool.mttkrp_unfolded(&skew_unf, &skew_krp).unwrap();
        });
        let _ = t;
    }

    rec.finish();
}
