//! COORD — L3 coordinator scaling: wall-clock time of one distributed
//! MTTKRP vs worker count (the leader/worker pool over simulated arrays),
//! plus queue-depth (backpressure) sensitivity.

#[path = "common/mod.rs"]
mod common;

use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::tensor::Matrix;
use psram_imc::util::prng::Prng;

fn main() {
    let mut rng = Prng::new(13);
    // 16 images (4 K-blocks x 4 R-blocks), 20 lane batches each.
    let unf = Matrix::randn(1040, 1024, &mut rng);
    let krp = Matrix::randn(1024, 128, &mut rng);

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    common::section(&format!(
        "COORD: distributed MTTKRP wall-clock vs workers ({cores} core(s) available)"
    ));
    if cores == 1 {
        println!("NOTE: single-core machine — parallel speedup is physically impossible;");
        println!("      this bench then measures coordination OVERHEAD (should be ~flat).");
    }
    let mut t1 = 0.0;
    for &workers in &[1usize, 2, 4, 8] {
        let t = common::bench(&format!("mttkrp 1040x1024x128 workers={workers}"), 1, 3, || {
            let mut pool = Coordinator::spawn(
                CoordinatorConfig { workers, queue_depth: 2 * workers },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            pool.mttkrp_unfolded(unf.clone(), &krp).unwrap();
        });
        if workers == 1 {
            t1 = t;
        } else {
            println!("  -> speedup vs 1 worker: {:.2}x", t1 / t);
        }
    }

    common::section("COORD: queue-depth (backpressure) sensitivity @ 4 workers");
    for &depth in &[1usize, 4, 16] {
        common::bench(&format!("mttkrp queue_depth={depth}"), 1, 3, || {
            let mut pool = Coordinator::spawn(
                CoordinatorConfig { workers: 4, queue_depth: depth },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            pool.mttkrp_unfolded(unf.clone(), &krp).unwrap();
        });
    }
}
