//! AB-ARRAY — ablation over array geometry: peak/sustained scaling from
//! the model, plus measured simulator throughput per geometry.

#[path = "common/mod.rs"]
mod common;

use psram_imc::compute::ComputeEngine;
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::psram::{ArrayGeometry, PsramArray};
use psram_imc::util::prng::Prng;
use psram_imc::util::units::format_ops;

fn main() {
    common::section("AB-ARRAY: model — sustained performance vs array geometry");
    let w = Workload::paper_large();
    println!(
        "{:>10} | {:>9} | {:>16} | {:>16} | {:>8}",
        "geometry", "words", "peak", "sustained", "util"
    );
    for &dim in &[64usize, 128, 256, 512] {
        let geom = ArrayGeometry::new(dim, dim, 8).unwrap();
        let mut m = PerfModel::paper();
        m.geom = geom;
        let est = m.predict(&w).unwrap();
        println!(
            "{:>10} | {:>9} | {:>16} | {:>16} | {:>8.4}",
            format!("{dim}x{dim}"),
            geom.total_words(),
            format_ops(est.peak_ops),
            format_ops(est.sustained_raw_ops),
            est.utilization
        );
    }
    println!("(larger arrays amortise one wordline write over more bits: peak and");
    println!(" sustained grow ~quadratically with the array edge)");

    common::section("AB-ARRAY: measured — simulator compute-cycle cost per geometry");
    let mut rng = Prng::new(5);
    for &dim in &[64usize, 128, 256] {
        let geom = ArrayGeometry::new(dim, dim, 8).unwrap();
        let mut array = PsramArray::new(geom).unwrap();
        let img: Vec<i8> = (0..geom.total_words()).map(|_| rng.next_i8()).collect();
        array.write_image(&img).unwrap();
        let lanes = 16usize;
        let u: Vec<u8> = (0..lanes * dim).map(|_| rng.next_u8()).collect();
        let mut eng = ComputeEngine::ideal();
        let macs = (dim * geom.words_per_row() * lanes) as f64;
        let t = common::bench(&format!("compute_cycle {dim}x{dim} lanes=16"), 3, 20, || {
            eng.compute_cycle(&mut array, &u, lanes).unwrap();
        });
        println!("  -> {:.3e} MAC/s simulated", macs / t);
    }
}
