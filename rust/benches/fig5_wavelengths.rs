//! FIG5i — regenerates Fig. 5(i): sustained MTTKRP performance vs number
//! of wavelength channels, from (a) the predictive model on the paper's
//! 1M-per-mode workload and (b) *measured* utilisation of the functional
//! pipeline on a scaled-down workload with the same reuse structure.

#[path = "common/mod.rs"]
mod common;

use psram_imc::mttkrp::pipeline::{CpuTileExecutor, PsramPipeline};
use psram_imc::perfmodel::fig5_wavelengths;
use psram_imc::tensor::Matrix;
use psram_imc::util::prng::Prng;
use psram_imc::util::stats::linear_fit;
use psram_imc::util::units::format_ops;

fn main() {
    common::section("Fig 5(i): sustained performance vs wavelength channels (model)");
    let channels: Vec<usize> = vec![1, 2, 4, 8, 12, 16, 24, 32, 40, 52, 64];
    let pts = fig5_wavelengths(&channels, 20e9).unwrap();
    println!("{:>9} | {:>16} | {:>8} | {}", "channels", "sustained", "util", "PDK");
    for p in &pts {
        println!(
            "{:>9} | {:>16} | {:>8.4} | {}",
            p.x,
            format_ops(p.sustained_ops),
            p.utilization,
            if p.admissible { "ok" } else { "extrapolated" }
        );
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.x).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.sustained_ops).collect();
    let (_, slope, r2) = linear_fit(&xs, &ys);
    println!("series linearity: R²={r2:.6} slope={}/channel", format_ops(slope));
    assert!(r2 > 0.999, "Fig 5(i) must be linear");

    common::section("Fig 5(i) measured: pipeline utilisation vs channels (scaled workload)");
    // Reuse-heavy scaled workload: I = 2000*λ rows so every channel count
    // sees the same lane-batch count (isolates the λ effect), K=256, R=32.
    let mut rng = Prng::new(1);
    println!("{:>9} | {:>10} | {:>10} | {:>12}", "channels", "meas util", "pred util", "sim time");
    for &l in &[4usize, 16, 52] {
        let i_dim = 400 * l;
        let unf = Matrix::randn(i_dim, 256, &mut rng);
        let krp = Matrix::randn(256, 32, &mut rng);
        let mut exec = CpuTileExecutor::new(256, 32, l);
        let mut pipe = PsramPipeline::new(&mut exec);
        let t = common::bench(&format!("mttkrp λ={l} I={i_dim}"), 1, 3, || {
            let mut e2 = CpuTileExecutor::new(256, 32, l);
            let mut p2 = PsramPipeline::new(&mut e2);
            p2.mttkrp_unfolded(&unf, &krp).unwrap();
        });
        pipe.mttkrp_unfolded(&unf, &krp).unwrap();
        let meas = pipe.stats.utilization();
        let pred = {
            let mut m = psram_imc::perfmodel::PerfModel::paper();
            m.wavelengths = l;
            m.predict(&psram_imc::perfmodel::Workload {
                i_rows: i_dim as u64,
                k_contraction: 256,
                rank: 32,
            })
            .unwrap()
            .utilization
        };
        println!("{l:>9} | {meas:>10.4} | {pred:>10.4} | {:>12}", common::fmt_s(t));
        assert!((meas - pred).abs() < 1e-9, "model must match measurement");
    }
}
