//! Seeded chaos harness: deterministic fault schedules replayed against
//! both session engines.  The contract under test is the resilience
//! invariant from DESIGN.md — under ANY injected fault schedule every
//! submission is either bit-identical to the fault-free run or a typed
//! error; never silent corruption, never a hang, never a leaked worker.
//!
//! Replay: every schedule is a pure function of a seed.  Set `CHAOS_SEED`
//! to re-run the whole matrix under one specific seed, e.g.
//! `CHAOS_SEED=23 cargo test --release --test chaos`.

use psram_imc::fault::{
    silence_injected_death_panics, Backoff, FaultEvent, FaultInjector, FaultKind,
    FaultPlan, FaultPolicy, FaultSpec,
};
use psram_imc::session::{Engine, JobId, Kernel, PsramSession};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;
use psram_imc::Error;
use std::sync::Arc;

/// The fixed seed matrix CI replays, overridable with `CHAOS_SEED=<u64>`.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => vec![s.trim().parse().expect("CHAOS_SEED must be a u64")],
        Err(_) => vec![11, 23, 47],
    }
}

/// A small dense problem whose per-mode plans each hold exactly one
/// stored image on the paper geometry, so worker-local load indices
/// advance one per submission and every drawn schedule is replayable.
fn problem(seed: u64) -> (DenseTensor, Vec<Matrix>) {
    let mut rng = Prng::new(seed);
    let x = DenseTensor::randn(&[20, 8, 8], &mut rng);
    let factors: Vec<Matrix> =
        [20, 8, 8].iter().map(|&d| Matrix::randn(d, 8, &mut rng)).collect();
    (x, factors)
}

/// Fault-free references, one per mode, from a pristine session.
fn references(x: &DenseTensor, factors: &[Matrix]) -> Vec<Matrix> {
    let clean = PsramSession::builder().build().unwrap();
    (0..3)
        .map(|mode| clean.run(Kernel::DenseMttkrp { x, factors, mode }).unwrap())
        .collect()
}

fn injector(plan: &FaultPlan) -> Arc<FaultInjector> {
    Arc::new(FaultInjector::new(plan))
}

/// The schedule shapes the matrix sweeps: each fault class alone, then
/// all of them at once.
fn spec_matrix() -> Vec<(&'static str, FaultSpec)> {
    let base = FaultSpec {
        workers: 1,
        horizon_loads: 12,
        upsets: 0,
        upset_bits: 4,
        transients: 0,
        deaths: 0,
    };
    vec![
        ("transients", FaultSpec { transients: 3, ..base }),
        ("upsets", FaultSpec { upsets: 3, ..base }),
        ("deaths", FaultSpec { deaths: 2, ..base }),
        ("mixed", FaultSpec { upsets: 2, transients: 2, deaths: 1, ..base }),
    ]
}

#[test]
fn chaos_matrix_bit_identical_or_typed_error() {
    // Every seed x schedule-shape x engine cell: twelve submissions under
    // a generous recovery policy.  Each one must reproduce the fault-free
    // bits exactly or surface a typed, classified error — the injector
    // cannot manufacture a silently wrong matrix.
    silence_injected_death_panics();
    for seed in chaos_seeds() {
        let (x, factors) = problem(seed);
        let refs = references(&x, &factors);
        for (label, spec) in spec_matrix() {
            for engine in [Engine::SingleArray, Engine::Coordinated { shards: 1 }] {
                let plan = FaultPlan::from_seed(seed, &spec);
                let inj = injector(&plan);
                let session = PsramSession::builder()
                    .engine(engine)
                    .fault_injector(Arc::clone(&inj))
                    .fault_policy(FaultPolicy {
                        retries: 4,
                        backoff: Backoff::none(),
                        respawn_budget: 4,
                        ..FaultPolicy::default()
                    })
                    .build()
                    .unwrap();
                for rep in 0..4 {
                    for mode in 0..3 {
                        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
                        match session.run(k) {
                            Ok(got) => assert_eq!(
                                got.data(),
                                refs[mode].data(),
                                "seed {seed} {label} {engine:?} rep {rep} mode \
                                 {mode}: corrupted result escaped recovery"
                            ),
                            Err(e) => assert!(
                                matches!(e, Error::Fault(_) | Error::Coordinator(_)),
                                "seed {seed} {label} {engine:?}: untyped error {e}"
                            ),
                        }
                    }
                }
                // Injected totals never exceed the schedule (events that
                // collide on one load index are consumed together but an
                // early-returning transient/death leaves the rest of the
                // slot uncounted), and whatever recovery ran is visible
                // in the job's counters.
                let (u, t, d) = inj.injected();
                assert!((u + t + d) as usize + inj.remaining() <= plan.len());
                // (Scrub visibility is pinned exactly in
                // `recovery_counters_land_in_job_metrics_and_ledger`; here
                // an upset whose bit flips cancel pairwise may legally
                // leave the checksum intact and need no scrub.)
                let jm = session.job_metrics(JobId::DEFAULT);
                assert!(jm.requests <= 12);
            }
        }
    }
}

#[test]
fn chaos_replay_is_deterministic_per_seed() {
    // Same seed, same spec, fresh sessions: the schedule, the injected
    // counters, and every submission outcome (bits or error text) must
    // replay identically — the property `CHAOS_SEED` relies on.
    silence_injected_death_panics();
    for seed in chaos_seeds() {
        let (x, factors) = problem(seed);
        let spec = FaultSpec {
            workers: 1,
            horizon_loads: 8,
            upsets: 2,
            upset_bits: 3,
            transients: 2,
            deaths: 1,
        };
        let run = || {
            let inj = injector(&FaultPlan::from_seed(seed, &spec));
            let session = PsramSession::builder()
                .fault_injector(Arc::clone(&inj))
                .fault_policy(FaultPolicy {
                    retries: 2,
                    backoff: Backoff::none(),
                    ..FaultPolicy::default()
                })
                .build()
                .unwrap();
            let mut outcomes: Vec<std::result::Result<Vec<f32>, String>> = Vec::new();
            for mode in 0..3 {
                let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
                outcomes.push(
                    session.run(k).map(|m| m.data().to_vec()).map_err(|e| e.to_string()),
                );
            }
            (outcomes, inj.injected())
        };
        let (a, ia) = run();
        let (b, ib) = run();
        assert_eq!(ia, ib, "seed {seed}: injected counters diverged on replay");
        assert_eq!(a, b, "seed {seed}: outcomes diverged on replay");
    }
}

#[test]
fn recovery_counters_land_in_job_metrics_and_ledger() {
    // One explicit schedule, one fault class per submission, so every
    // recovery counter is an exact expectation rather than a bound.
    silence_injected_death_panics();
    let (x, factors) = problem(7);
    let refs = references(&x, &factors);
    let events = vec![
        FaultEvent { worker: 0, load_idx: 0, kind: FaultKind::Transient },
        FaultEvent { worker: 0, load_idx: 2, kind: FaultKind::ImageUpset { bits: 3 } },
        FaultEvent { worker: 0, load_idx: 3, kind: FaultKind::WorkerDeath },
    ];
    let inj = injector(&FaultPlan::new(31, events));
    let session = PsramSession::builder()
        .engine(Engine::Coordinated { shards: 1 })
        .fault_injector(Arc::clone(&inj))
        .fault_policy(FaultPolicy { backoff: Backoff::none(), ..FaultPolicy::default() })
        .build()
        .unwrap();
    // Submission 1: loads 0 (transient, retried) + 1.  Submission 2:
    // load 2 (upset, scrubbed).  Submission 3: load 3 (death; the batch
    // is re-queued onto the respawned worker, whose own load 0 event is
    // already consumed).  Submission 4: clean.
    for i in 0..4 {
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let got = session.run(k).unwrap();
        assert_eq!(got.data(), refs[0].data(), "submission {i} not bit-exact");
    }
    assert_eq!(inj.injected(), (1, 1, 1));
    assert_eq!(inj.remaining(), 0);

    let jm = session.job_metrics(JobId::DEFAULT);
    assert_eq!(jm.requests, 4);
    assert_eq!(jm.retries, 1);
    assert_eq!(jm.scrubs, 1);
    assert_eq!(jm.scrub_write_cycles, 256, "one full-image rewrite of 256 rows");
    assert_eq!(jm.fallbacks, 0);

    use std::sync::atomic::Ordering;
    let m = session.metrics();
    assert_eq!(m.batch_retries.load(Ordering::Relaxed), 1);
    assert_eq!(m.requeued_batches.load(Ordering::Relaxed), 1);
    assert_eq!(m.worker_deaths.load(Ordering::Relaxed), 1);
    assert_eq!(m.worker_respawns.load(Ordering::Relaxed), 1);
    assert_eq!(m.scrubs.load(Ordering::Relaxed), 1);
    assert_eq!(m.scrub_write_cycles.load(Ordering::Relaxed), 256);
}

#[test]
fn exhausted_budgets_surface_typed_errors_then_fallback_heals() {
    // Retry budget 0 + a transient on every load: the strict session
    // surfaces the typed transient fault; the same schedule with
    // `fallback` reroutes to the exact digital engine bit-for-bit.
    let (x, factors) = problem(8);
    let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 1 };
    let storm = || {
        injector(&FaultPlan::new(
            9,
            (0..16)
                .map(|i| FaultEvent {
                    worker: 0,
                    load_idx: i,
                    kind: FaultKind::Transient,
                })
                .collect(),
        ))
    };

    let strict = PsramSession::builder()
        .fault_injector(storm())
        .fault_policy(FaultPolicy {
            retries: 0,
            backoff: Backoff::none(),
            ..FaultPolicy::default()
        })
        .build()
        .unwrap();
    let err = strict.run(k).unwrap_err();
    assert!(err.is_transient_fault(), "want a typed transient fault, got {err}");

    let degraded = PsramSession::builder()
        .fault_injector(storm())
        .fault_policy(FaultPolicy {
            retries: 0,
            backoff: Backoff::none(),
            fallback: true,
            ..FaultPolicy::default()
        })
        .build()
        .unwrap();
    let got = degraded.run(k).unwrap();
    assert_eq!(got.data(), k.run_exact().unwrap().data());
    let jm = degraded.job_metrics(JobId::DEFAULT);
    assert_eq!(jm.fallbacks, 1);
    assert_eq!(jm.requests, 1);
}

#[test]
fn scrub_disabled_detects_corruption_instead_of_hiding_it() {
    // With scrubbing off, detection still runs: an upset becomes a typed
    // fault, never a silently corrupted matrix.
    let (x, factors) = problem(9);
    let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
    let inj = injector(&FaultPlan::new(
        4,
        // An odd flip count can never cancel pairwise back to a clean
        // checksum, so detection is guaranteed.
        vec![FaultEvent {
            worker: 0,
            load_idx: 0,
            kind: FaultKind::ImageUpset { bits: 3 },
        }],
    ));
    let session = PsramSession::builder()
        .fault_injector(Arc::clone(&inj))
        .fault_policy(FaultPolicy {
            scrub: false,
            retries: 0,
            backoff: Backoff::none(),
            ..FaultPolicy::default()
        })
        .build()
        .unwrap();
    let err = session.run(k).unwrap_err();
    assert!(matches!(err, Error::Fault(_)), "{err}");
    assert!(err.to_string().contains("scrub disabled"), "{err}");
    assert_eq!(inj.injected(), (1, 0, 0));
}
