//! Steady-state allocation accounting for the plan execution engine.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warm-up run has grown every scratch buffer, a full `execute_plan_into`
//! pass over dense and sparse plans — and an in-place `replan_into` — must
//! perform **zero** heap allocations.  This pins the zero-allocation
//! contract of `compute_into` / `compute_block_into` /
//! `quant_matmul_i32_into` / the arena-backed plan split end to end: no
//! per-cycle result vectors, no per-image partial churn, no per-call
//! scratch.
//!
//! Keep this file to a single `#[test]`: the counter is process-global,
//! and a concurrently running sibling test would perturb the count.

use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::mttkrp::plan::{
    execute_plan_into, DensePlanner, PlanScratch, SparseSlicePlanner,
};
use psram_imc::mttkrp::MttkrpStats;
use psram_imc::tensor::{CooTensor, Matrix};
use psram_imc::tune::TuneParams;
use psram_imc::util::prng::Prng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// System allocator wrapper counting every allocation event.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_plan_execution_allocates_nothing() {
    let mut rng = Prng::new(42);

    // Dense: 2 K-blocks × 2 R-blocks × 3 lane batches.
    let unf = Matrix::randn(120, 300, &mut rng);
    let krp_a = Matrix::randn(300, 40, &mut rng);
    let krp_b = Matrix::randn(300, 40, &mut rng);
    let planner = DensePlanner::new(256, 32, 52);
    let mut dense_plan = planner.plan_unfolded(&unf, &krp_a).unwrap();

    // Sparse: 2 stored-factor groups, slice-chunked streams with CP2
    // scale vectors.
    let shape = [24usize, 300, 8];
    let coo = CooTensor::random(&shape, 500, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 16, &mut rng)).collect();
    let sparse_planner = SparseSlicePlanner::new(256, 32, 52);
    let sparse_plan = sparse_planner.plan(&coo, &factors, 0).unwrap();

    let mut exec = CpuTileExecutor::paper();
    let mut stats = MttkrpStats::default();
    let mut scratch = PlanScratch::default();
    let mut dense_out = Matrix::zeros(120, 40);
    let mut sparse_out = Matrix::zeros(24, 16);

    // Warm-up: grows the scratch (tile block buffer, partials) once.
    execute_plan_into(&mut exec, &dense_plan, &mut scratch, &mut stats, &mut dense_out)
        .unwrap();
    execute_plan_into(&mut exec, &sparse_plan, &mut scratch, &mut stats, &mut sparse_out)
        .unwrap();
    let warm_dense = dense_out.data().to_vec();
    let warm_sparse = sparse_out.data().to_vec();

    // Steady state: repeated full executions allocate nothing.
    let before = allocs();
    for _ in 0..3 {
        execute_plan_into(
            &mut exec,
            &dense_plan,
            &mut scratch,
            &mut stats,
            &mut dense_out,
        )
        .unwrap();
        execute_plan_into(
            &mut exec,
            &sparse_plan,
            &mut scratch,
            &mut stats,
            &mut sparse_out,
        )
        .unwrap();
    }
    let steady = allocs() - before;
    assert_eq!(
        steady, 0,
        "steady-state execute_plan_into made {steady} heap allocations"
    );
    // ... and still computes the right bits.
    assert_eq!(dense_out.data(), &warm_dense[..]);
    assert_eq!(sparse_out.data(), &warm_sparse[..]);

    // In-place requantization is allocation-free too: the cached arena is
    // uniquely held, so `Arc::make_mut` never clones.
    let before = allocs();
    planner.replan_into(None, &krp_b, &mut dense_plan).unwrap();
    let replan = allocs() - before;
    assert_eq!(replan, 0, "replan_into made {replan} heap allocations");

    // The refilled plan executes without allocating either.
    let before = allocs();
    execute_plan_into(&mut exec, &dense_plan, &mut scratch, &mut stats, &mut dense_out)
        .unwrap();
    let steady = allocs() - before;
    assert_eq!(steady, 0, "post-replan execution made {steady} allocations");
    let warm_b = dense_out.data().to_vec();

    // A tuned executor obeys the same contract: the intra-shard pool's
    // threads are spawned at construction and its epoch handoff is
    // futex-based, so after one warm-up (which grows the tuned-size tile
    // scratch) the striped steady state allocates nothing either — per
    // worker or otherwise.
    let tuned = TuneParams { block_cycles: 64, intra_workers: 2 };
    let mut texec = CpuTileExecutor::paper().with_tuning(&tuned);
    let mut tscratch = PlanScratch::default();
    execute_plan_into(&mut texec, &dense_plan, &mut tscratch, &mut stats, &mut dense_out)
        .unwrap();
    let before = allocs();
    for _ in 0..3 {
        execute_plan_into(
            &mut texec,
            &dense_plan,
            &mut tscratch,
            &mut stats,
            &mut dense_out,
        )
        .unwrap();
    }
    let steady = allocs() - before;
    assert_eq!(
        steady, 0,
        "tuned/striped execute_plan_into made {steady} heap allocations"
    );
    assert_eq!(dense_out.data(), &warm_b[..]);
}
