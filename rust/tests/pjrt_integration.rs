//! Integration tests for the PJRT runtime layer.  These need `artifacts/`
//! (run `make artifacts` first — the Makefile test target does).

use psram_imc::mttkrp::pipeline::{
    AnalogTileExecutor, CpuTileExecutor, PsramPipeline, TileExecutor,
};
use psram_imc::mttkrp::reference::dense_mttkrp;
use psram_imc::runtime::{find_artifacts_dir, Manifest, PjrtRuntime, PjrtTileExecutor};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::fixed::quant_matmul_ref;
use psram_imc::util::prng::Prng;

#[test]
fn artifacts_exist_and_manifest_has_all_variants() {
    let dir = find_artifacts_dir().expect("run `make artifacts` first");
    let man = Manifest::load(&dir).unwrap();
    assert!(man.paper_tile().is_some());
    assert!(man.tile(64, 256, 16).is_some());
    assert!(man.tile(128, 512, 32).is_some());
    assert!(man.other("mttkrp_f32_64x48x40_r16").is_some());
    assert!(man.other("mttkrp_f32_32x24x20_r8").is_some());
}

#[test]
fn tile_kernel_matches_integer_reference() {
    let mut rt = PjrtRuntime::new().unwrap();
    let mut rng = Prng::new(1);
    for (m, k, n) in [(52usize, 256usize, 32usize), (64, 256, 16), (128, 512, 32)] {
        let name = format!("psram_tile_{m}x{k}x{n}");
        let u: Vec<u8> = (0..m * k).map(|_| rng.next_u8()).collect();
        let w: Vec<i8> = (0..k * n).map(|_| rng.next_i8()).collect();
        let got = rt.execute_tile(&name, &u, &w, m, k, n).unwrap();
        let want = quant_matmul_ref(&u, &w, m, k, n);
        assert_eq!(got, want, "variant {name}");
    }
}

#[test]
fn tile_kernel_extreme_inputs() {
    let mut rt = PjrtRuntime::new().unwrap();
    let (m, k, n) = (52usize, 256usize, 32usize);
    let name = "psram_tile_52x256x32";
    // max positive inputs against most-negative weights
    let u = vec![255u8; m * k];
    let w = vec![-128i8; k * n];
    let got = rt.execute_tile(name, &u, &w, m, k, n).unwrap();
    assert!(got.iter().all(|&v| v == (255 - 128) * -128 * 256));
    // zero code (value 0) against anything
    let u0 = vec![128u8; m * k];
    let got0 = rt.execute_tile(name, &u0, &w, m, k, n).unwrap();
    assert!(got0.iter().all(|&v| v == 0));
}

#[test]
fn tile_shape_validation() {
    let mut rt = PjrtRuntime::new().unwrap();
    let u = vec![0u8; 10];
    let w = vec![0i8; 10];
    assert!(rt.execute_tile("psram_tile_52x256x32", &u, &w, 52, 256, 32).is_err());
    assert!(rt
        .execute_tile("no_such_artifact", &[0; 52 * 256], &[0; 256 * 32], 52, 256, 32)
        .is_err());
}

#[test]
fn f32_baseline_matches_rust_reference() {
    let mut rt = PjrtRuntime::new().unwrap();
    let mut rng = Prng::new(2);
    let (i, j, k, r) = (32usize, 24usize, 20usize, 8usize);
    let x = DenseTensor::randn(&[i, j, k], &mut rng);
    let b = Matrix::randn(j, r, &mut rng);
    let c = Matrix::randn(k, r, &mut rng);
    let got = rt
        .execute_mttkrp_f32(
            "mttkrp_f32_32x24x20_r8",
            x.data(),
            b.data(),
            c.data(),
            i,
            j,
            k,
            r,
        )
        .unwrap();
    let want = dense_mttkrp(&x, &[Matrix::zeros(i, r), b, c], 0).unwrap();
    assert_eq!(got.len(), want.data().len());
    for (g, w) in got.iter().zip(want.data()) {
        assert!((g - w).abs() < 1e-3, "{g} vs {w}");
    }
}

#[test]
fn pjrt_executor_bit_exact_with_cpu_and_analog_in_pipeline() {
    let mut rng = Prng::new(3);
    let x = DenseTensor::randn(&[61, 9, 31], &mut rng);
    let factors: Vec<Matrix> =
        [61, 9, 31].iter().map(|&d| Matrix::randn(d, 5, &mut rng)).collect();

    let mut cpu = CpuTileExecutor::paper();
    let out_cpu = PsramPipeline::new(&mut cpu).mttkrp(&x, &factors, 0).unwrap();

    let mut analog = AnalogTileExecutor::ideal();
    let out_analog = PsramPipeline::new(&mut analog).mttkrp(&x, &factors, 0).unwrap();

    let mut pjrt = PjrtTileExecutor::paper().unwrap();
    let out_pjrt = PsramPipeline::new(&mut pjrt).mttkrp(&x, &factors, 0).unwrap();

    assert_eq!(out_cpu.data(), out_analog.data());
    assert_eq!(out_cpu.data(), out_pjrt.data());
}

#[test]
fn pjrt_executor_pads_partial_lane_batches() {
    // 7 lanes < 52: executor must pad to the artifact's static M and slice.
    let mut rng = Prng::new(4);
    let mut pjrt = PjrtTileExecutor::paper().unwrap();
    let mut cpu = CpuTileExecutor::paper();
    let image: Vec<i8> = (0..256 * 32).map(|_| rng.next_i8()).collect();
    pjrt.load_image(&image).unwrap();
    cpu.load_image(&image).unwrap();
    let u: Vec<u8> = (0..7 * 256).map(|_| rng.next_u8()).collect();
    assert_eq!(pjrt.compute(&u, 7).unwrap(), cpu.compute(&u, 7).unwrap());
}

#[test]
fn pjrt_executor_cycle_accounting_matches_cpu() {
    let mut rng = Prng::new(5);
    let x = DenseTensor::randn(&[30, 8, 8], &mut rng);
    let factors: Vec<Matrix> =
        [30, 8, 8].iter().map(|&d| Matrix::randn(d, 4, &mut rng)).collect();
    let mut cpu = CpuTileExecutor::paper();
    PsramPipeline::new(&mut cpu).mttkrp(&x, &factors, 0).unwrap();
    let mut pjrt = PjrtTileExecutor::paper().unwrap();
    PsramPipeline::new(&mut pjrt).mttkrp(&x, &factors, 0).unwrap();
    assert_eq!(cpu.cycles(), pjrt.cycles());
}
