//! Bit-identity and census-invariance pins for the tuned digital
//! execution path: geometry-driven streaming chunks
//! (`TileExecutor::block_cycles`) and the intra-shard worker pool
//! (`mttkrp::par::IntraPool`).
//!
//! The contract under test (DESIGN.md §7, `tune` module docs): tuning is
//! **bit-invisible** — for any `block_cycles >= 1` and any intra-shard
//! width, the f32 results, the `MttkrpStats` census, and the executor's
//! `CycleLedger` are identical to the untuned sequential executor, on
//! dense and sparse plans alike.  This is what lets the autotuner pick
//! whatever chunking is fastest without touching the committed
//! `BENCH_*.json` baselines.

use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::mttkrp::pipeline::TileExecutor;
use psram_imc::mttkrp::plan::{
    execute_plan, DensePlanner, SparseSlicePlanner, TilePlan, BLOCK_CYCLES,
};
use psram_imc::mttkrp::{CpuTileExecutor, MttkrpStats};
use psram_imc::psram::CycleLedger;
use psram_imc::tensor::{CooTensor, Matrix};
use psram_imc::tune::TuneParams;
use psram_imc::util::prng::Prng;

type Census = (u64, u64, u64, u64, u64);

fn census(s: &MttkrpStats) -> Census {
    (s.images, s.compute_cycles, s.write_cycles, s.useful_macs, s.raw_macs)
}

/// Execute `plan` on a fresh executor tuned with `params`; return the
/// result bits, the stats census, and the executor's cycle ledger.
fn run(plan: &TilePlan, params: TuneParams) -> (Vec<f32>, Census, CycleLedger) {
    let mut exec = CpuTileExecutor::paper().with_tuning(&params);
    let mut stats = MttkrpStats::default();
    let out = execute_plan(&mut exec, plan, &mut stats).unwrap();
    (out.data().to_vec(), census(&stats), exec.cycles())
}

/// 2 K-blocks × 2 R-blocks × 3 lane batches (52 + 52 + 16-lane tail).
fn dense_plan() -> TilePlan {
    let mut rng = Prng::new(31);
    let unf = Matrix::randn(120, 300, &mut rng);
    let krp = Matrix::randn(300, 40, &mut rng);
    DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp).unwrap()
}

/// Slice-grouped sparse plan: many short, ragged stream blocks — the
/// case where chunk boundaries and stripe assignment move the most.
fn sparse_plan() -> TilePlan {
    let mut rng = Prng::new(32);
    let shape = [24usize, 300, 8];
    let coo = CooTensor::random(&shape, 500, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 16, &mut rng)).collect();
    SparseSlicePlanner::new(256, 32, 52).plan(&coo, &factors, 0).unwrap()
}

#[test]
fn intra_parallel_execution_is_bit_identical_to_sequential() {
    for (name, plan) in [("dense", dense_plan()), ("sparse", sparse_plan())] {
        let baseline = run(&plan, TuneParams::default());
        for workers in [1usize, 2, 4] {
            let got = run(
                &plan,
                TuneParams { intra_workers: workers, ..TuneParams::default() },
            );
            assert_eq!(got, baseline, "{name} plan, workers={workers}");
        }
    }
}

#[test]
fn census_is_invariant_under_any_chunking() {
    for (name, plan) in [("dense", dense_plan()), ("sparse", sparse_plan())] {
        let baseline = run(&plan, TuneParams::default());
        for bc in [1usize, 3, 8, 52, 128] {
            for workers in [1usize, 3] {
                let got =
                    run(&plan, TuneParams { block_cycles: bc, intra_workers: workers });
                assert_eq!(
                    got, baseline,
                    "{name} plan, block_cycles={bc} workers={workers}"
                );
            }
        }
    }
}

#[test]
fn tuned_executor_reports_its_parameters() {
    let tuned = CpuTileExecutor::paper()
        .with_tuning(&TuneParams { block_cycles: 52, intra_workers: 3 });
    assert_eq!(tuned.block_cycles(), 52);
    assert_eq!(tuned.intra_workers(), 3);
    let untuned = CpuTileExecutor::paper();
    assert_eq!(untuned.block_cycles(), BLOCK_CYCLES);
    assert_eq!(untuned.intra_workers(), 1);
}

#[test]
fn coordinator_with_tuned_workers_is_bit_identical() {
    let mut rng = Prng::new(33);
    // 3 K-blocks × 2 R-blocks = 6 images over 3 shard keys.
    let unf = Matrix::randn(130, 600, &mut rng);
    let krp = Matrix::randn(600, 48, &mut rng);
    let plan = DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp).unwrap();
    let (want, want_census, _) = run(&plan, TuneParams::default());

    let tuned = TuneParams { block_cycles: 17, intra_workers: 2 };
    let mut pool = Coordinator::spawn(
        CoordinatorConfig::new(2),
        |_| Ok(CpuTileExecutor::paper().with_tuning(&tuned)),
    )
    .unwrap();
    let got = pool.execute_plan(&plan).unwrap();
    assert_eq!(got.data(), &want[..], "pooled tuned result must match sequential");

    let snap = pool.metrics().snapshot();
    let get = |key: &str| {
        snap.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(0)
    };
    let (images, compute, write, _, _) = want_census;
    assert_eq!(get("images"), images);
    assert_eq!(get("compute_cycles"), compute);
    assert_eq!(get("write_cycles"), write);
}
