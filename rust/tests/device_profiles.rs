//! The device-profile subsystem contract:
//!
//! * `baseline` lowers bit-identically onto the pre-profile stack — the
//!   perf model, energy model, compute engine, and full sessions all pin
//!   to their `paper()`/`ideal()`/default twins.
//! * Profile calibration moves the *models* (clocks, conversion energy),
//!   never the computed numbers: sessions built from any registry profile
//!   stay bit-identical to the default session.
//! * The X-pSRAM binary-op (XOR) kernel's measured census equals
//!   `PerfModel::predict_xor` for any lane batching, and the kernel is a
//!   typed error on bitcells without embedded XOR.

use psram_imc::compute::ComputeEngine;
use psram_imc::device::profiles::{self, baseline_psram, eo_adc, x_psram_xor};
use psram_imc::energy::EnergyModel;
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::psram::PsramArray;
use psram_imc::session::{Engine, Kernel, PsramSession};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::fixed::encode_offset;
use psram_imc::util::prng::Prng;
use psram_imc::util::proptest::{check_with, Config};
use psram_imc::Error;

// ---------------------------------------------------------------------------
// Baseline pins: profile-calibrated constructors == the legacy defaults.
// ---------------------------------------------------------------------------

#[test]
fn perf_model_from_baseline_is_field_identical_to_paper() {
    let a = PerfModel::from_profile(&baseline_psram());
    let b = PerfModel::paper();
    assert_eq!(a.geom.rows, b.geom.rows);
    assert_eq!(a.geom.cols_bits, b.geom.cols_bits);
    assert_eq!(a.geom.word_bits, b.geom.word_bits);
    assert_eq!(a.wavelengths, b.wavelengths);
    assert_eq!(a.clock_hz, b.clock_hz);
    assert_eq!(a.write_clock_hz, b.write_clock_hz);
    assert_eq!(a.double_buffer, b.double_buffer);
    assert_eq!(a.num_arrays, b.num_arrays);
}

#[test]
fn energy_model_from_baseline_matches_paper_term_for_term() {
    let w = Workload::paper_large();
    let a = EnergyModel::from_profile(&baseline_psram());
    let b = EnergyModel::paper();
    let ea = a.predict(&a.model.predict(&w).unwrap());
    let eb = b.predict(&b.model.predict(&w).unwrap());
    // Identical inputs through identical formulas: exact f64 equality.
    assert_eq!(ea.switching_j, eb.switching_j);
    assert_eq!(ea.static_j, eb.static_j);
    assert_eq!(ea.modulator_j, eb.modulator_j);
    assert_eq!(ea.adc_j, eb.adc_j);
    assert_eq!(ea.laser_j, eb.laser_j);
    assert_eq!(
        ea.per_op_j(2.0 * w.useful_macs()),
        eb.per_op_j(2.0 * w.useful_macs())
    );
}

#[test]
fn engine_from_baseline_is_behaviourally_identical_to_ideal() {
    let mut rng = Prng::new(7);
    let img: Vec<i8> = (0..256 * 32).map(|_| rng.next_i8()).collect();
    let u: Vec<u8> = (0..52 * 256).map(|_| encode_offset(i32::from(rng.next_i8()))).collect();

    let mut a = ComputeEngine::ideal();
    let mut b = ComputeEngine::from_profile(&baseline_psram());
    assert!(a.is_exact() && b.is_exact());
    assert!(b.binary_ops().is_none(), "baseline latch embeds no XOR");

    let mut arr_a = PsramArray::paper();
    let mut arr_b = PsramArray::paper();
    arr_a.write_image(&img).unwrap();
    arr_b.write_image(&img).unwrap();
    let out_a = a.compute_cycle(&mut arr_a, &u, 52).unwrap();
    let out_b = b.compute_cycle(&mut arr_b, &u, 52).unwrap();
    assert_eq!(out_a, out_b);
    assert_eq!(a.stats.cycles, b.stats.cycles);
    assert_eq!(a.stats.macs, b.stats.macs);
    assert_eq!(arr_a.energy.total_j(), arr_b.energy.total_j());
}

// ---------------------------------------------------------------------------
// Sessions: profiles calibrate models, never bits.
// ---------------------------------------------------------------------------

#[test]
fn prop_profile_sessions_bit_identical_to_default_session() {
    // Any registry profile, both executor families, dense MTTKRP and TTM:
    // the profile-built session reproduces the default session bit for
    // bit.  (All shipped profiles are NoiseSpec::Off and lower onto
    // exact-readout functional devices — calibration moves predictions,
    // not arithmetic.)
    check_with(
        "profile sessions == default session",
        Config { cases: 6, max_size: 16, seed: 0xDE7 },
        |case| {
            let rng = &mut case.rng;
            let d0 = 4 + rng.below(4 + case.size as u64) as usize;
            let d1 = 3 + rng.below(3 + case.size as u64) as usize;
            let d2 = 2 + rng.below(2 + case.size as u64 / 2) as usize;
            let r = 1 + rng.below(8) as usize;
            let shape = [d0, d1, d2];
            let x = DenseTensor::randn(&shape, rng);
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, rng)).collect();
            let mode = rng.below(3) as usize;
            let analog = rng.below(2) == 1;

            let reference = PsramSession::builder()
                .engine(Engine::SingleArray)
                .analog(analog)
                .build()
                .map_err(|e| e.to_string())?;
            let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode };
            let want = reference.run(k).map_err(|e| e.to_string())?;

            for p in profiles::all() {
                let session = PsramSession::builder()
                    .engine(Engine::SingleArray)
                    .analog(analog)
                    .device_profile(&p)
                    .build()
                    .map_err(|e| e.to_string())?;
                let got = session.run(k).map_err(|e| e.to_string())?;
                if got.data() != want.data() {
                    return Err(format!(
                        "profile '{}' diverged (mode {mode}, analog {analog})",
                        p.name
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eo_adc_model_raises_reads_but_not_writes() {
    let base = PerfModel::from_profile(&baseline_psram());
    let eo = PerfModel::from_profile(&eo_adc());
    assert_eq!(eo.clock_hz, 25e9);
    assert_eq!(eo.write_clock_hz, base.write_clock_hz);

    let w = Workload::paper_large();
    let eb = base.predict(&w).unwrap();
    let ee = eo.predict(&w).unwrap();
    // Compute cycles are clock-independent counts; writes are charged in
    // compute-clock units, so the 25/20 ratio shows up there.
    assert_eq!(ee.compute_cycles, eb.compute_cycles);
    assert_eq!(ee.write_cycles, eb.write_cycles * 5 / 4);
    assert!(ee.peak_ops > eb.peak_ops);
    assert!(ee.sustained_raw_ops > eb.sustained_raw_ops);
    assert!(ee.utilization < eb.utilization, "writes stall 25 GHz reads longer");
    assert!(ee.runtime_s < eb.runtime_s);

    // The EO converter is cheaper per conversion than the ideal-SAR stand-in.
    let per_op = |em: &EnergyModel| {
        let est = em.model.predict(&w).unwrap();
        em.predict(&est).per_op_j(2.0 * w.useful_macs())
    };
    assert!(
        per_op(&EnergyModel::from_profile(&eo_adc()))
            < per_op(&EnergyModel::from_profile(&baseline_psram()))
    );
}

// ---------------------------------------------------------------------------
// X-pSRAM binary-op kernel: predicted == measured census.
// ---------------------------------------------------------------------------

#[test]
fn prop_xor_census_predicted_equals_measured_for_any_lane_batching() {
    check_with(
        "xor census == predict_xor",
        Config { cases: 24, max_size: 120, seed: 0x0B17 },
        |case| {
            let rng = &mut case.rng;
            let vectors = 1 + rng.below(1 + case.size as u64) as usize;
            let mut array = PsramArray::paper();
            let img: Vec<i8> =
                (0..array.geometry().total_words()).map(|_| rng.next_i8()).collect();
            array.write_image(&img).map_err(|e| e.to_string())?;
            let rows = array.geometry().rows;
            let wpr = array.geometry().words_per_row();
            let bits: Vec<u8> = (0..vectors * rows).map(|_| rng.next_u8() & 1).collect();

            // Full packing: 52-lane cycles plus one ragged remainder.
            let mut full = vec![52usize; vectors / 52];
            if vectors % 52 != 0 {
                full.push(vectors % 52);
            }
            let mut engine = ComputeEngine::from_profile(&x_psram_xor());
            let mut out = vec![0u32; vectors * wpr];
            engine
                .xor_block_into(&mut array, &bits, &full, &mut out)
                .map_err(|e| e.to_string())?;

            let est = PerfModel::from_profile(&x_psram_xor())
                .predict_xor(vectors as u64)
                .map_err(|e| e.to_string())?;
            psram_imc::prop_assert_eq!(engine.stats.xor_cycles, est.xor_cycles);
            psram_imc::prop_assert_eq!(engine.stats.bit_ops, est.bit_ops);

            // An arbitrary ragged batching pays more cycles but performs the
            // same bit-ops and produces identical Hamming distances.
            let mut ragged = Vec::new();
            let mut left = vectors;
            while left > 0 {
                let take = (1 + rng.below(52) as usize).min(left);
                ragged.push(take);
                left -= take;
            }
            let mut engine2 = ComputeEngine::from_profile(&x_psram_xor());
            let mut out2 = vec![0u32; vectors * wpr];
            engine2
                .xor_block_into(&mut array, &bits, &ragged, &mut out2)
                .map_err(|e| e.to_string())?;
            psram_imc::prop_assert_eq!(engine2.stats.bit_ops, est.bit_ops);
            psram_imc::prop_assert_eq!(out, out2);
            psram_imc::prop_assert!(
                engine2.stats.xor_cycles >= est.xor_cycles,
                "ragged batching can only add cycles"
            );
            Ok(())
        },
    );
}

#[test]
fn xor_kernel_is_typed_error_without_embedded_xor_bitcell() {
    let mut array = PsramArray::paper();
    let bits = vec![0u8; 256];
    for p in [baseline_psram(), eo_adc()] {
        let mut engine = ComputeEngine::from_profile(&p);
        let err = engine.xor_cycle(&mut array, &bits, 1).unwrap_err();
        assert!(matches!(err, Error::Device(_)), "profile '{}': {err}", p.name);
        assert!(err.to_string().contains("x_psram_xor"), "{err}");
    }
    // And the profile that embeds it succeeds on the same inputs.
    let mut engine = ComputeEngine::from_profile(&x_psram_xor());
    let out = engine.xor_cycle(&mut array, &bits, 1).unwrap();
    // Zeroed array, all-zero input bits: every Hamming distance is 0.
    assert!(out.iter().all(|&v| v == 0));
    assert_eq!(engine.stats.xor_cycles, 1);
}

#[test]
fn registry_names_resolve_and_unknown_is_typed() {
    for name in profiles::NAMES {
        assert_eq!(profiles::by_name(name).unwrap().name, name);
    }
    assert_eq!(profiles::by_name("baseline_psram").unwrap().name, "baseline");
    let err = profiles::by_name("tachyon").unwrap_err();
    assert!(matches!(err, Error::Device(_)), "{err}");
}
