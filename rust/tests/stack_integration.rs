//! Whole-stack integration: CP-ALS through every backend, coordinator over
//! analog arrays, and cross-backend agreement.  The PJRT tests additionally
//! need `artifacts/` and the `xla` feature.

use psram_imc::compute::ComputeEngine;
use psram_imc::coordinator::pool::{CoordinatedBackend, CoordinatedSparseBackend};
use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::cpd::{AlsConfig, CpAls, ExactBackend, MttkrpBackend, PsramBackend};
use psram_imc::device::{DeviceParams, NoiseModel};
use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor};
use psram_imc::mttkrp::plan::{DensePlanner, SparseSlicePlanner, TilePlan, TtmPlanner};
use psram_imc::mttkrp::reference::sparse_mttkrp;
use psram_imc::mttkrp::SparsePsramPipeline;
use psram_imc::perfmodel::PerfModel;
use psram_imc::psram::PsramArray;
#[cfg(feature = "xla")]
use psram_imc::runtime::PjrtTileExecutor;
use psram_imc::tensor::{CooTensor, DenseTensor, Matrix};
use psram_imc::tucker::{
    tucker_fit, tucker_reconstruct, CoordinatedTtmBackend, PsramTtmBackend,
    TtmBackend, TtmStream, TuckerConfig, TuckerHooi,
};
use psram_imc::util::prng::Prng;

fn low_rank(seed: u64, shape: &[usize], r: usize, noise: f32) -> DenseTensor {
    let mut rng = Prng::new(seed);
    let f: Vec<Matrix> = shape.iter().map(|&d| Matrix::randn(d, r, &mut rng)).collect();
    DenseTensor::from_cp_factors(&f, noise, &mut rng).unwrap()
}

// Needs the AOT artifacts and the `xla` feature (PJRT bindings).
#[cfg(feature = "xla")]
#[test]
fn cp_als_through_pjrt_backend_reaches_high_fit() {
    let x = low_rank(1, &[20, 16, 12], 3, 0.0);
    let exec = PjrtTileExecutor::paper().unwrap();
    let mut backend = PsramBackend::new(&x, exec);
    let res = CpAls::new(AlsConfig { rank: 3, max_iters: 25, tol: 1e-6, seed: 11 })
        .run_backend(&mut backend)
        .unwrap();
    assert!(res.final_fit() > 0.95, "fit={}", res.final_fit());
}

// Needs the AOT artifacts and the `xla` feature (PJRT bindings).
#[cfg(feature = "xla")]
#[test]
fn pjrt_and_analog_backends_identical_fit_history() {
    // Both executors are bit-exact, so the whole ALS trajectory must match.
    let x = low_rank(2, &[18, 14, 10], 3, 0.02);
    let cfg = AlsConfig { rank: 3, max_iters: 8, tol: 0.0, seed: 5 };

    let mut b1 = PsramBackend::new(&x, PjrtTileExecutor::paper().unwrap());
    let r1 = CpAls::new(cfg.clone()).run_backend(&mut b1).unwrap();

    let mut b2 = PsramBackend::new(&x, AnalogTileExecutor::ideal());
    let r2 = CpAls::new(cfg).run_backend(&mut b2).unwrap();

    assert_eq!(r1.fit_history, r2.fit_history);
    assert_eq!(r1.lambda, r2.lambda);
}

#[test]
fn coordinator_over_analog_arrays_matches_cpu_workers() {
    // Workers simulating real pSRAM arrays vs plain integer workers:
    // identical results (and the analog path charges energy).
    let mut rng = Prng::new(3);
    let x = DenseTensor::randn(&[80, 10, 30], &mut rng);
    let factors: Vec<Matrix> =
        [80, 10, 30].iter().map(|&d| Matrix::randn(d, 6, &mut rng)).collect();

    let mut analog_pool = Coordinator::spawn(
        CoordinatorConfig { workers: 3, queue_depth: 4, ..Default::default() },
        |_| Ok(AnalogTileExecutor::ideal()),
    )
    .unwrap();
    let a = analog_pool.mttkrp(&x, &factors, 0).unwrap();

    let mut cpu_pool = Coordinator::spawn(
        CoordinatorConfig { workers: 2, queue_depth: 4, ..Default::default() },
        |_| Ok(CpuTileExecutor::paper()),
    )
    .unwrap();
    let b = cpu_pool.mttkrp(&x, &factors, 0).unwrap();

    assert_eq!(a.data(), b.data());
}

#[test]
fn noisy_analog_backend_still_decomposes() {
    // Detector noise at a few LSB: CP-ALS must still converge to a useful
    // fit (the robustness claim behind analog IMC).
    let x = low_rank(4, &[24, 20, 16], 3, 0.0);
    let engine = ComputeEngine::new(
        DeviceParams::default(),
        NoiseModel::gaussian(2.0, 99),
    );
    let exec = AnalogTileExecutor::new(engine, PsramArray::paper());
    let mut backend = PsramBackend::new(&x, exec);
    let res = CpAls::new(AlsConfig { rank: 3, max_iters: 30, tol: 1e-6, seed: 21 })
        .run_backend(&mut backend)
        .unwrap();
    // verify with the ground-truth fit (the identity-based one is not
    // trustworthy under noise)
    let fit = psram_imc::cpd::brute_force_fit(&x, &res.factors, &res.lambda);
    assert!(fit > 0.9, "fit={fit}");
}

#[test]
fn noise_sweep_degrades_true_fit() {
    // The internal (identity-based) fit is unreliable under analog noise —
    // it trusts the noisy MTTKRP.  Verify with the brute-force fit instead:
    // moderate sigma is absorbed by the LS averaging; extreme sigma breaks
    // the decomposition.
    let x = low_rank(5, &[20, 16, 12], 2, 0.0);
    let mut fits = Vec::new();
    for &sigma in &[0.0f64, 2e3, 2e6] {
        let engine = ComputeEngine::new(
            DeviceParams::default(),
            NoiseModel::gaussian(sigma, 7),
        );
        let exec = AnalogTileExecutor::new(engine, PsramArray::paper());
        let mut backend = PsramBackend::new(&x, exec);
        let res = CpAls::new(AlsConfig { rank: 2, max_iters: 20, tol: 1e-7, seed: 3 })
            .run_backend(&mut backend)
            .unwrap();
        fits.push(psram_imc::cpd::brute_force_fit(&x, &res.factors, &res.lambda));
    }
    assert!(fits[0] > 0.95, "clean fit {}", fits[0]);
    assert!(fits[1] > 0.8, "moderate noise should be mostly absorbed: {}", fits[1]);
    assert!(fits[2] < fits[0] - 0.05, "extreme noise must hurt: fits={fits:?}");
}

#[test]
fn exact_vs_quantized_fit_gap_is_small() {
    let x = low_rank(6, &[22, 18, 14], 4, 0.05);
    let mut exact = ExactBackend { tensor: &x };
    let rexact = CpAls::new(AlsConfig { rank: 4, max_iters: 30, tol: 1e-6, seed: 8 })
        .run_backend(&mut exact)
        .unwrap();
    let mut quant = PsramBackend::new(&x, CpuTileExecutor::paper());
    let rquant = CpAls::new(AlsConfig { rank: 4, max_iters: 30, tol: 1e-6, seed: 8 })
        .run_backend(&mut quant)
        .unwrap();
    let gap = rexact.final_fit() - rquant.final_fit();
    assert!(gap.abs() < 0.05, "exact {} quant {}", rexact.final_fit(), rquant.final_fit());
}

#[test]
fn coordinator_sparse_bit_identical_for_any_worker_count_and_steal_schedule() {
    // j_dim = 600 -> 3 stored-factor blocks, rank 40 -> 2 images per
    // group, so sharding, batch chunking and stealing are all exercised.
    let mut rng = Prng::new(31);
    let shape = [40usize, 600, 18];
    let x = CooTensor::random(&shape, 2000, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 40, &mut rng)).collect();

    let mut exec = CpuTileExecutor::paper();
    let single = SparsePsramPipeline::new(&mut exec).mttkrp(&x, &factors, 0).unwrap();

    // The quantized result approximates the exact sparse MTTKRP...
    let exact = sparse_mttkrp(&x, &factors, 0).unwrap();
    let norm = exact.fro_norm().max(1e-9);
    let err: f64 = exact
        .data()
        .iter()
        .zip(single.data())
        .map(|(e, a)| ((e - a) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    assert!(err / norm < 0.05, "quantized sparse MTTKRP off by {}", err / norm);

    // ...and every coordinator schedule reproduces it bit-exactly.
    for workers in [1usize, 2, 4] {
        for steal in [false, true] {
            for batch_size in [1usize, 2] {
                let mut pool = Coordinator::spawn(
                    CoordinatorConfig {
                        workers,
                        batch_size,
                        steal,
                        ..CoordinatorConfig::new(workers)
                    },
                    |_| Ok(CpuTileExecutor::paper()),
                )
                .unwrap();
                let dist = pool.sparse_mttkrp(&x, &factors, 0).unwrap();
                assert_eq!(
                    single.data(),
                    dist.data(),
                    "workers={workers} steal={steal} batch={batch_size}"
                );
            }
        }
    }
}

/// Assert `predict_plan`'s cycle census equals what a fresh pool measures
/// when it executes the same plan (paper clocks: write cycles are already
/// in compute-clock units, so the comparison is exact).
fn assert_predicted_equals_measured(plan: &TilePlan, run: impl FnOnce(&mut Coordinator)) {
    let mut model = PerfModel::paper();
    model.num_arrays = 3;
    let est = model.predict_plan(plan).unwrap();
    let mut pool = Coordinator::spawn(CoordinatorConfig::new(3), |_| {
        Ok(CpuTileExecutor::paper())
    })
    .unwrap();
    run(&mut pool);
    let snap = pool.metrics().snapshot();
    assert_eq!(est.images, snap[1].1, "images");
    assert_eq!(est.compute_cycles, snap[2].1, "compute cycles");
    assert_eq!(est.reconfig_write_cycles, snap[3].1, "reconfiguration writes");
    assert_eq!(est.useful_macs, snap[4].1, "useful MACs");
    assert_eq!(est.raw_macs, snap[5].1, "raw MACs");
    assert!(
        (est.utilization - pool.metrics().utilization()).abs() < 1e-12,
        "utilization: predicted {} vs measured {}",
        est.utilization,
        pool.metrics().utilization()
    );
    // The per-shard split sums to the predicted totals.
    let rows = pool.metrics().shard_snapshot();
    let streamed: u64 = rows.iter().map(|r| r.streamed_cycles).sum();
    let reconfig: u64 = rows.iter().map(|r| r.reconfig_write_cycles).sum();
    assert_eq!(streamed, est.compute_cycles);
    assert_eq!(reconfig, est.reconfig_write_cycles);
}

#[test]
fn predict_plan_matches_coordinator_measured_cycles_dense_and_sparse() {
    let mut rng = Prng::new(33);

    // Dense workload: 3 K-block groups x 2 rank blocks x 3 lane batches.
    let unf = Matrix::randn(150, 700, &mut rng);
    let krp = Matrix::randn(700, 48, &mut rng);
    let plan = DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp).unwrap();
    assert_predicted_equals_measured(&plan, |pool| {
        pool.mttkrp_unfolded(&unf, &krp).unwrap();
    });

    // Sparse workload: 3 stored-factor groups, slice-chunked streams.
    let shape = [30usize, 520, 12];
    let x = CooTensor::random(&shape, 900, &mut rng);
    let factors: Vec<Matrix> =
        shape.iter().map(|&d| Matrix::randn(d, 24, &mut rng)).collect();
    let plan = SparseSlicePlanner::new(256, 32, 52).plan(&x, &factors, 0).unwrap();
    assert_predicted_equals_measured(&plan, |pool| {
        pool.sparse_mttkrp(&x, &factors, 0).unwrap();
    });
}

#[test]
fn predict_plan_matches_coordinator_measured_cycles_ttm() {
    // The Tucker TTM workload gets the same cycle-exact predicted ==
    // measured treatment as dense and sparse MTTKRP: 3 contraction-block
    // groups x 2 rank blocks, distributed over 3 shards.
    let mut rng = Prng::new(34);
    let x = DenseTensor::randn(&[700, 25, 6], &mut rng);
    let u = Matrix::randn(700, 48, &mut rng);
    let plan = TtmPlanner::new(256, 32, 52).plan_ttm(&x, &u, 0).unwrap();
    assert_predicted_equals_measured(&plan, |pool| {
        pool.execute_plan(&plan).unwrap();
    });
}

/// A deliberately cache-free TTM backend: materialises the streamed
/// operand and plans every contraction from scratch.  Used to pin the
/// plan-cached Tucker backends bit-exactly to uncached planning.
struct UncachedTtm {
    pool: Coordinator,
}

impl TtmBackend for UncachedTtm {
    fn ttm(
        &mut self,
        _slot: usize,
        stream: TtmStream<'_>,
        u: &Matrix,
    ) -> psram_imc::Result<Matrix> {
        let xt = stream.to_matrix()?;
        let plan = self.pool.ttm_planner().plan_streamed(&xt, u)?;
        self.pool.execute_plan(&plan)
    }
}

#[test]
fn plan_cached_hooi_identical_to_uncached_planning() {
    // The per-chain-slot TTM plan cache must not change a single bit of
    // the HOOI trajectory: iterations 2..N requantize cached arenas in
    // place, and the fit history, factors, and core have to equal planning
    // from scratch every call — on the coordinator *and* on a single
    // array (all three share the quantization + accumulation contract).
    let mut rng = Prng::new(43);
    let core = DenseTensor::randn(&[3, 3, 3], &mut rng);
    let truth: Vec<Matrix> =
        [22usize, 16, 12].iter().map(|&d| Matrix::randn(d, 3, &mut rng)).collect();
    let x = tucker_reconstruct(&core, &truth).unwrap();
    let hooi = TuckerHooi::new(TuckerConfig {
        ranks: vec![3, 3, 3],
        max_iters: 8,
        tol: 0.0,
    });

    let spawn = || Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut cached = CoordinatedTtmBackend::new(spawn());
    let r1 = hooi.run_backend(&x, &mut cached).unwrap();
    let mut uncached = UncachedTtm { pool: spawn() };
    let r2 = hooi.run_backend(&x, &mut uncached).unwrap();
    assert_eq!(r1.fit_history, r2.fit_history);
    assert_eq!(r1.core.data(), r2.core.data());
    for (a, b) in r1.factors.iter().zip(&r2.factors) {
        assert_eq!(a.data(), b.data());
    }

    // The single-array cached backend joins the same bit-identical family.
    let mut single = PsramTtmBackend::new(CpuTileExecutor::paper());
    let r3 = hooi.run_backend(&x, &mut single).unwrap();
    assert_eq!(r1.fit_history, r3.fit_history);
    assert_eq!(r1.core.data(), r3.core.data());
}

#[test]
fn coordinated_hooi_over_analog_arrays_decomposes() {
    // End to end: Tucker/HOOI on a pool of simulated analog arrays
    // recovers an exact low-multilinear-rank tensor.
    let mut rng = Prng::new(44);
    let core = DenseTensor::randn(&[2, 2, 2], &mut rng);
    let truth: Vec<Matrix> =
        [18usize, 14, 10].iter().map(|&d| Matrix::randn(d, 2, &mut rng)).collect();
    let x = tucker_reconstruct(&core, &truth).unwrap();
    let pool = Coordinator::spawn(
        CoordinatorConfig { workers: 3, queue_depth: 4, ..Default::default() },
        |_| Ok(AnalogTileExecutor::ideal()),
    )
    .unwrap();
    let mut backend = CoordinatedTtmBackend::new(pool);
    let res = TuckerHooi::new(TuckerConfig::new(vec![2, 2, 2]))
        .run_backend(&x, &mut backend)
        .unwrap();
    let fit = tucker_fit(&x, &res.core, &res.factors).unwrap();
    assert!(fit > 0.95, "fit={fit}");
    assert!(backend.pool.metrics().snapshot()[1].1 > 0); // images
}

/// A deliberately cache-free coordinator backend: plans every MTTKRP from
/// scratch through `Coordinator::mttkrp` / `sparse_mttkrp`.  Used to pin
/// the plan-cached default backends bit-exactly to uncached planning.
struct UncachedDense<'a> {
    tensor: &'a DenseTensor,
    pool: Coordinator,
}

impl MttkrpBackend for UncachedDense<'_> {
    fn mttkrp(&mut self, factors: &[Matrix], mode: usize) -> psram_imc::Result<Matrix> {
        self.pool.mttkrp(self.tensor, factors, mode)
    }
    fn shape(&self) -> &[usize] {
        self.tensor.shape()
    }
    fn norm_sq(&self) -> f64 {
        let n = self.tensor.fro_norm();
        n * n
    }
}

struct UncachedSparse<'a> {
    tensor: &'a CooTensor,
    pool: Coordinator,
}

impl MttkrpBackend for UncachedSparse<'_> {
    fn mttkrp(&mut self, factors: &[Matrix], mode: usize) -> psram_imc::Result<Matrix> {
        self.pool.sparse_mttkrp(self.tensor, factors, mode)
    }
    fn shape(&self) -> &[usize] {
        self.tensor.shape()
    }
    fn norm_sq(&self) -> f64 {
        self.tensor.values().iter().map(|&v| (v as f64) * (v as f64)).sum()
    }
}

#[test]
fn plan_cached_als_identical_to_uncached_planning() {
    // The per-mode plan caches must not change a single bit of the ALS
    // trajectory: iterations 2..N requantize cached arenas in place, and
    // the fit history has to equal planning from scratch every call.
    let x = low_rank(41, &[26, 18, 14], 3, 0.02);
    let cfg = AlsConfig { rank: 3, max_iters: 12, tol: 0.0, seed: 5 };

    let spawn = || Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut cached = CoordinatedBackend::new(&x, spawn());
    let r1 = CpAls::new(cfg.clone()).run_backend(&mut cached).unwrap();
    let mut uncached = UncachedDense { tensor: &x, pool: spawn() };
    let r2 = CpAls::new(cfg.clone()).run_backend(&mut uncached).unwrap();
    assert_eq!(r1.fit_history, r2.fit_history);
    assert_eq!(r1.lambda, r2.lambda);
    for (a, b) in r1.factors.iter().zip(&r2.factors) {
        assert_eq!(a.data(), b.data());
    }

    // Sparse: same invariant through the slice-wise plans.
    let coo = CooTensor::from_dense(&x, 0.0);
    let mut cached = CoordinatedSparseBackend::new(&coo, spawn());
    let r3 = CpAls::new(cfg.clone()).run_backend(&mut cached).unwrap();
    let mut uncached = UncachedSparse { tensor: &coo, pool: spawn() };
    let r4 = CpAls::new(cfg).run_backend(&mut uncached).unwrap();
    assert_eq!(r3.fit_history, r4.fit_history);
    assert_eq!(r3.lambda, r4.lambda);
}

#[test]
fn coordinated_sparse_cp_als_decomposes_sparsified_low_rank() {
    let mut rng = Prng::new(36);
    let truth: Vec<Matrix> =
        [16usize, 14, 12].iter().map(|&d| Matrix::randn(d, 2, &mut rng)).collect();
    let dense = DenseTensor::from_cp_factors(&truth, 0.0, &mut rng).unwrap();
    let coo = CooTensor::from_dense(&dense, 0.0); // fully dense in COO form
    let pool = Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut backend = CoordinatedSparseBackend::new(&coo, pool);
    // best of 3 starts (ALS is init-sensitive)
    let mut best = 0.0f64;
    for seed in [2u64, 3, 4] {
        let res = CpAls::new(AlsConfig { rank: 2, max_iters: 30, tol: 1e-7, seed })
            .run_backend(&mut backend)
            .unwrap();
        best = best.max(res.final_fit());
    }
    assert!(best > 0.95, "fit={best}");
    assert!(backend.pool.metrics().snapshot()[1].1 > 0); // images
}

#[test]
fn coordinated_cp_als_with_many_workers() {
    let x = low_rank(7, &[40, 24, 20], 4, 0.0);
    let pool = Coordinator::spawn(
        CoordinatorConfig { workers: 6, queue_depth: 12, ..Default::default() },
        |_| Ok(CpuTileExecutor::paper()),
    )
    .unwrap();
    let mut backend = CoordinatedBackend::new(&x, pool);
    let res = CpAls::new(AlsConfig { rank: 4, max_iters: 25, tol: 1e-6, seed: 12 })
        .run_backend(&mut backend)
        .unwrap();
    assert!(res.final_fit() > 0.9, "fit={}", res.final_fit());
    let m = backend.pool.metrics();
    assert!(m.snapshot()[1].1 > 0); // images
}
