//! Whole-stack integration: CP-ALS through every backend, coordinator over
//! analog arrays, and cross-backend agreement.  The PJRT tests additionally
//! need `artifacts/` and the `xla` feature.

use psram_imc::compute::ComputeEngine;
use psram_imc::coordinator::pool::CoordinatedBackend;
use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::cpd::{AlsConfig, CpAls, ExactBackend, PsramBackend};
use psram_imc::device::{DeviceParams, NoiseModel};
use psram_imc::mttkrp::pipeline::{AnalogTileExecutor, CpuTileExecutor};
use psram_imc::psram::PsramArray;
#[cfg(feature = "xla")]
use psram_imc::runtime::PjrtTileExecutor;
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;

fn low_rank(seed: u64, shape: &[usize], r: usize, noise: f32) -> DenseTensor {
    let mut rng = Prng::new(seed);
    let f: Vec<Matrix> = shape.iter().map(|&d| Matrix::randn(d, r, &mut rng)).collect();
    DenseTensor::from_cp_factors(&f, noise, &mut rng).unwrap()
}

// Needs the AOT artifacts and the `xla` feature (PJRT bindings).
#[cfg(feature = "xla")]
#[test]
fn cp_als_through_pjrt_backend_reaches_high_fit() {
    let x = low_rank(1, &[20, 16, 12], 3, 0.0);
    let exec = PjrtTileExecutor::paper().unwrap();
    let mut backend = PsramBackend::new(&x, exec);
    let res = CpAls::new(AlsConfig { rank: 3, max_iters: 25, tol: 1e-6, seed: 11 })
        .run(&mut backend)
        .unwrap();
    assert!(res.final_fit() > 0.95, "fit={}", res.final_fit());
}

// Needs the AOT artifacts and the `xla` feature (PJRT bindings).
#[cfg(feature = "xla")]
#[test]
fn pjrt_and_analog_backends_identical_fit_history() {
    // Both executors are bit-exact, so the whole ALS trajectory must match.
    let x = low_rank(2, &[18, 14, 10], 3, 0.02);
    let cfg = AlsConfig { rank: 3, max_iters: 8, tol: 0.0, seed: 5 };

    let mut b1 = PsramBackend::new(&x, PjrtTileExecutor::paper().unwrap());
    let r1 = CpAls::new(cfg.clone()).run(&mut b1).unwrap();

    let mut b2 = PsramBackend::new(&x, AnalogTileExecutor::ideal());
    let r2 = CpAls::new(cfg).run(&mut b2).unwrap();

    assert_eq!(r1.fit_history, r2.fit_history);
    assert_eq!(r1.lambda, r2.lambda);
}

#[test]
fn coordinator_over_analog_arrays_matches_cpu_workers() {
    // Workers simulating real pSRAM arrays vs plain integer workers:
    // identical results (and the analog path charges energy).
    let mut rng = Prng::new(3);
    let x = DenseTensor::randn(&[80, 10, 30], &mut rng);
    let factors: Vec<Matrix> =
        [80, 10, 30].iter().map(|&d| Matrix::randn(d, 6, &mut rng)).collect();

    let mut analog_pool = Coordinator::spawn(
        CoordinatorConfig { workers: 3, queue_depth: 4, ..Default::default() },
        |_| Ok(AnalogTileExecutor::ideal()),
    )
    .unwrap();
    let a = analog_pool.mttkrp(&x, &factors, 0).unwrap();

    let mut cpu_pool = Coordinator::spawn(
        CoordinatorConfig { workers: 2, queue_depth: 4, ..Default::default() },
        |_| Ok(CpuTileExecutor::paper()),
    )
    .unwrap();
    let b = cpu_pool.mttkrp(&x, &factors, 0).unwrap();

    assert_eq!(a.data(), b.data());
}

#[test]
fn noisy_analog_backend_still_decomposes() {
    // Detector noise at a few LSB: CP-ALS must still converge to a useful
    // fit (the robustness claim behind analog IMC).
    let x = low_rank(4, &[24, 20, 16], 3, 0.0);
    let engine = ComputeEngine::new(
        DeviceParams::default(),
        NoiseModel::gaussian(2.0, 99),
    );
    let exec = AnalogTileExecutor::new(engine, PsramArray::paper());
    let mut backend = PsramBackend::new(&x, exec);
    let res = CpAls::new(AlsConfig { rank: 3, max_iters: 30, tol: 1e-6, seed: 21 })
        .run(&mut backend)
        .unwrap();
    // verify with the ground-truth fit (the identity-based one is not
    // trustworthy under noise)
    let fit = psram_imc::cpd::brute_force_fit(&x, &res.factors, &res.lambda);
    assert!(fit > 0.9, "fit={fit}");
}

#[test]
fn noise_sweep_degrades_true_fit() {
    // The internal (identity-based) fit is unreliable under analog noise —
    // it trusts the noisy MTTKRP.  Verify with the brute-force fit instead:
    // moderate sigma is absorbed by the LS averaging; extreme sigma breaks
    // the decomposition.
    let x = low_rank(5, &[20, 16, 12], 2, 0.0);
    let mut fits = Vec::new();
    for &sigma in &[0.0f64, 2e3, 2e6] {
        let engine = ComputeEngine::new(
            DeviceParams::default(),
            NoiseModel::gaussian(sigma, 7),
        );
        let exec = AnalogTileExecutor::new(engine, PsramArray::paper());
        let mut backend = PsramBackend::new(&x, exec);
        let res = CpAls::new(AlsConfig { rank: 2, max_iters: 20, tol: 1e-7, seed: 3 })
            .run(&mut backend)
            .unwrap();
        fits.push(psram_imc::cpd::brute_force_fit(&x, &res.factors, &res.lambda));
    }
    assert!(fits[0] > 0.95, "clean fit {}", fits[0]);
    assert!(fits[1] > 0.8, "moderate noise should be mostly absorbed: {}", fits[1]);
    assert!(fits[2] < fits[0] - 0.05, "extreme noise must hurt: fits={fits:?}");
}

#[test]
fn exact_vs_quantized_fit_gap_is_small() {
    let x = low_rank(6, &[22, 18, 14], 4, 0.05);
    let mut exact = ExactBackend { tensor: &x };
    let rexact = CpAls::new(AlsConfig { rank: 4, max_iters: 30, tol: 1e-6, seed: 8 })
        .run(&mut exact)
        .unwrap();
    let mut quant = PsramBackend::new(&x, CpuTileExecutor::paper());
    let rquant = CpAls::new(AlsConfig { rank: 4, max_iters: 30, tol: 1e-6, seed: 8 })
        .run(&mut quant)
        .unwrap();
    let gap = rexact.final_fit() - rquant.final_fit();
    assert!(gap.abs() < 0.05, "exact {} quant {}", rexact.final_fit(), rquant.final_fit());
}

#[test]
fn coordinated_cp_als_with_many_workers() {
    let x = low_rank(7, &[40, 24, 20], 4, 0.0);
    let pool = Coordinator::spawn(
        CoordinatorConfig { workers: 6, queue_depth: 12, ..Default::default() },
        |_| Ok(CpuTileExecutor::paper()),
    )
    .unwrap();
    let mut backend = CoordinatedBackend { tensor: &x, pool };
    let res = CpAls::new(AlsConfig { rank: 4, max_iters: 25, tol: 1e-6, seed: 12 })
        .run(&mut backend)
        .unwrap();
    assert!(res.final_fit() > 0.9, "fit={}", res.final_fit());
    let m = backend.pool.metrics();
    assert!(m.snapshot()[1].1 > 0); // images
}
