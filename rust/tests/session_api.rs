//! The unified `PsramSession` surface: bit-identity against the legacy
//! per-kernel backends for all three kernels, per-job namespace isolation
//! under real concurrency, and the cycle-exact per-job
//! `predict == measured` contract.

use psram_imc::coordinator::pool::{CoordinatedBackend, CoordinatedSparseBackend};
use psram_imc::coordinator::Coordinator;
use psram_imc::cpd::{AlsConfig, CpAls, CpTarget, PsramBackend};
use psram_imc::mttkrp::pipeline::CpuTileExecutor;
use psram_imc::mttkrp::{SparsePsramBackend, SparsePsramPipeline};
use psram_imc::session::{CachePolicy, Engine, JobId, Kernel, PsramSession, SessionJob};
use psram_imc::tensor::{CooTensor, DenseTensor, Matrix};
use psram_imc::tucker::{
    CoordinatedTtmBackend, PsramTtmBackend, TtmStream, TuckerConfig, TuckerHooi,
};
use psram_imc::util::prng::Prng;
use psram_imc::util::proptest::{check_with, Config};

fn low_rank(seed: u64, shape: &[usize], r: usize, noise: f32) -> DenseTensor {
    let mut rng = Prng::new(seed);
    let f: Vec<Matrix> = shape.iter().map(|&d| Matrix::randn(d, r, &mut rng)).collect();
    DenseTensor::from_cp_factors(&f, noise, &mut rng).unwrap()
}

fn cpu_session(engine: Engine) -> PsramSession {
    PsramSession::builder().engine(engine).build().unwrap()
}

// ---------------------------------------------------------------------------
// Bit-identity: session vs the legacy backend path, all three kernels.
// ---------------------------------------------------------------------------

#[test]
fn prop_session_bit_identical_to_legacy_path_all_kernels() {
    // Random geometries/ranks; for each case the session (single-array,
    // cached) must reproduce the legacy per-kernel path bit for bit on a
    // dense MTTKRP, a sparse MTTKRP, and a TTM.
    check_with(
        "session == legacy backends, all kernels",
        Config { cases: 12, max_size: 24, seed: 0x5E55 },
        |case| {
            let rng = &mut case.rng;
            let d0 = 4 + rng.below(3 + case.size as u64) as usize;
            let d1 = 3 + rng.below(3 + case.size as u64) as usize;
            let d2 = 2 + rng.below(1 + case.size as u64 / 2) as usize;
            let r = 1 + rng.below(10) as usize;
            let shape = [d0, d1, d2];
            let x = DenseTensor::randn(&shape, rng);
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, rng)).collect();
            let mode = rng.below(3) as usize;

            let session = cpu_session(Engine::SingleArray);

            // Dense MTTKRP vs the legacy cached PsramBackend.
            use psram_imc::cpd::backend::MttkrpBackend;
            let mut legacy = PsramBackend::new(&x, CpuTileExecutor::paper());
            let want = legacy.mttkrp(&factors, mode).map_err(|e| e.to_string())?;
            let got = session
                .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode })
                .map_err(|e| e.to_string())?;
            if got.data() != want.data() {
                return Err(format!("dense kernel diverged (mode {mode})"));
            }

            // Sparse MTTKRP vs the legacy cached SparsePsramBackend.
            let coo = CooTensor::from_dense(&x, 0.0);
            let mut legacy = SparsePsramBackend::new(&coo, CpuTileExecutor::paper());
            let want = legacy.mttkrp(&factors, mode).map_err(|e| e.to_string())?;
            let got = session
                .run(Kernel::SparseMttkrp { x: &coo, factors: &factors, mode })
                .map_err(|e| e.to_string())?;
            if got.data() != want.data() {
                return Err(format!("sparse kernel diverged (mode {mode})"));
            }

            // TTM vs the legacy cached PsramTtmBackend.
            use psram_imc::tucker::backend::TtmBackend;
            let u = Matrix::randn(shape[mode], r, rng);
            let mut legacy = PsramTtmBackend::new(CpuTileExecutor::paper());
            let want = legacy
                .ttm(0, TtmStream::Fixed(&x, mode), &u)
                .map_err(|e| e.to_string())?;
            let got = session
                .run(Kernel::Ttm { stream: TtmStream::Fixed(&x, mode), u: &u, slot: 0 })
                .map_err(|e| e.to_string())?;
            if got.data() != want.data() {
                return Err(format!("ttm kernel diverged (mode {mode})"));
            }
            Ok(())
        },
    );
}

#[test]
fn coordinated_session_als_bit_identical_to_legacy_coordinated_backend() {
    let x = low_rank(21, &[26, 18, 14], 3, 0.02);
    let cfg = AlsConfig { rank: 3, max_iters: 10, tol: 0.0, seed: 5 };

    let pool = Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut legacy = CoordinatedBackend::new(&x, pool);
    let a = CpAls::new(cfg.clone()).run_backend(&mut legacy).unwrap();

    let session = cpu_session(Engine::Coordinated { shards: 3 });
    let b = CpAls::new(cfg).run(&session, CpTarget::Dense(&x)).unwrap();

    assert_eq!(a.fit_history, b.fit_history);
    assert_eq!(a.lambda, b.lambda);
    for (fa, fb) in a.factors.iter().zip(&b.factors) {
        assert_eq!(fa.data(), fb.data());
    }
}

#[test]
fn coordinated_session_sparse_als_bit_identical_to_legacy() {
    let x = low_rank(22, &[16, 14, 12], 2, 0.0);
    let coo = CooTensor::from_dense(&x, 0.0);
    let cfg = AlsConfig { rank: 2, max_iters: 8, tol: 0.0, seed: 3 };

    let pool = Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut legacy = CoordinatedSparseBackend::new(&coo, pool);
    let a = CpAls::new(cfg.clone()).run_backend(&mut legacy).unwrap();

    let session = cpu_session(Engine::Coordinated { shards: 3 });
    let b = CpAls::new(cfg).run(&session, CpTarget::Sparse(&coo)).unwrap();

    assert_eq!(a.fit_history, b.fit_history);
    assert_eq!(a.lambda, b.lambda);
}

#[test]
fn coordinated_session_hooi_bit_identical_to_legacy() {
    let mut rng = Prng::new(23);
    let core = DenseTensor::randn(&[2, 2, 2], &mut rng);
    let truth: Vec<Matrix> =
        [18usize, 14, 10].iter().map(|&d| Matrix::randn(d, 2, &mut rng)).collect();
    let x = psram_imc::tucker::tucker_reconstruct(&core, &truth).unwrap();
    let hooi =
        TuckerHooi::new(TuckerConfig { ranks: vec![2, 2, 2], max_iters: 6, tol: 0.0 });

    let pool = Coordinator::with_workers(3, |_| Ok(CpuTileExecutor::paper())).unwrap();
    let mut legacy = CoordinatedTtmBackend::new(pool);
    let a = hooi.run_backend(&x, &mut legacy).unwrap();

    let session = cpu_session(Engine::Coordinated { shards: 3 });
    let b = hooi.run(&x, &session).unwrap();

    assert_eq!(a.fit_history, b.fit_history);
    assert_eq!(a.core.data(), b.core.data());
    for (fa, fb) in a.factors.iter().zip(&b.factors) {
        assert_eq!(fa.data(), fb.data());
    }
}

#[test]
fn cache_policy_disabled_bit_identical_on_coordinated_engine() {
    let x = low_rank(24, &[20, 12, 10], 3, 0.01);
    let cfg = AlsConfig { rank: 3, max_iters: 6, tol: 0.0, seed: 9 };
    let cached = cpu_session(Engine::Coordinated { shards: 2 });
    let uncached = PsramSession::builder()
        .engine(Engine::Coordinated { shards: 2 })
        .cache(CachePolicy::Disabled)
        .build()
        .unwrap();
    let a = CpAls::new(cfg.clone()).run(&cached, CpTarget::Dense(&x)).unwrap();
    let b = CpAls::new(cfg).run(&uncached, CpTarget::Dense(&x)).unwrap();
    assert_eq!(a.fit_history, b.fit_history);
    // run_job releases its namespace on exit — neither session retains
    // plan arenas after the decomposition finishes.
    assert_eq!(cached.cached_plans(), 0);
    assert_eq!(uncached.cached_plans(), 0);
}

// ---------------------------------------------------------------------------
// Multi-tenancy: concurrent jobs on one pool.
// ---------------------------------------------------------------------------

/// Sum the predicted cycle census of `reps` submissions of each kernel,
/// through the job's own cache namespace (so the scored plans are the
/// executed plans).
fn predict_total(job: &SessionJob, kernels: &[Kernel<'_>], reps: u64) -> (u64, u64, u64) {
    let mut images = 0u64;
    let mut streamed = 0u64;
    let mut reconfig = 0u64;
    for k in kernels {
        let est = job.predict(k).unwrap();
        images += reps * est.images;
        streamed += reps * est.compute_cycles;
        reconfig += reps * est.reconfig_write_cycles;
    }
    (images, streamed, reconfig)
}

#[test]
fn concurrent_jobs_share_pool_with_cycle_exact_attribution() {
    // Two tenants, two threads, ONE coordinated session.  Each submits
    // its own kernels; afterwards every job's measured counters must
    // equal its predicted census exactly, the global counters must be
    // the per-job sum, and each job's results must be bit-identical to
    // an isolated single-array run.
    let (xa, fa) = {
        let mut rng = Prng::new(31);
        let x = DenseTensor::randn(&[60, 16, 20], &mut rng);
        let f: Vec<Matrix> =
            [60, 16, 20].iter().map(|&d| Matrix::randn(d, 24, &mut rng)).collect();
        (x, f)
    };
    let (xb, fb) = {
        let mut rng = Prng::new(32);
        let x = DenseTensor::randn(&[80, 12, 12], &mut rng);
        let f: Vec<Matrix> =
            [80, 12, 12].iter().map(|&d| Matrix::randn(d, 16, &mut rng)).collect();
        (x, f)
    };
    let session = cpu_session(Engine::Coordinated { shards: 3 });
    let job_a = session.job(JobId(1));
    let job_b = session.job(JobId(2));

    let kernels_a: Vec<Kernel<'_>> = (0..3)
        .map(|mode| Kernel::DenseMttkrp { x: &xa, factors: &fa, mode })
        .collect();
    let kernels_b: Vec<Kernel<'_>> = (0..3)
        .map(|mode| Kernel::DenseMttkrp { x: &xb, factors: &fb, mode })
        .collect();
    let reps = 2u64;

    let (mut out_a, mut out_b) = (Vec::new(), Vec::new());
    std::thread::scope(|scope| {
        let ja = &job_a;
        let jb = &job_b;
        let (ka, kb) = (&kernels_a, &kernels_b);
        let ha = scope.spawn(move || {
            let mut outs = Vec::new();
            for _ in 0..reps {
                for k in ka {
                    outs.push(ja.run(*k).unwrap());
                }
            }
            outs
        });
        let hb = scope.spawn(move || {
            let mut outs = Vec::new();
            for _ in 0..reps {
                for k in kb {
                    outs.push(jb.run(*k).unwrap());
                }
            }
            outs
        });
        out_a = ha.join().unwrap();
        out_b = hb.join().unwrap();
    });

    // Results are unaffected by tenancy: bit-identical to isolated runs.
    let solo = cpu_session(Engine::SingleArray);
    for (i, k) in kernels_a.iter().enumerate() {
        let want = solo.run(*k).unwrap();
        assert_eq!(out_a[i].data(), want.data(), "job A kernel {i}");
        assert_eq!(out_a[i + 3].data(), want.data(), "job A kernel {i} rep 2");
    }
    let solo_b = cpu_session(Engine::SingleArray);
    for (i, k) in kernels_b.iter().enumerate() {
        let want = solo_b.run(*k).unwrap();
        assert_eq!(out_b[i].data(), want.data(), "job B kernel {i}");
    }

    // Predicted == measured, per job, cycle-exactly.
    let (img_a, str_a, rec_a) = predict_total(&job_a, &kernels_a, reps);
    let (img_b, str_b, rec_b) = predict_total(&job_b, &kernels_b, reps);
    let ma = job_a.metrics();
    let mb = job_b.metrics();
    assert_eq!(ma.requests, reps * 3);
    assert_eq!(mb.requests, reps * 3);
    assert_eq!(ma.images, img_a, "job A images");
    assert_eq!(ma.streamed_cycles, str_a, "job A streamed cycles");
    assert_eq!(ma.reconfig_write_cycles, rec_a, "job A reconfig writes");
    assert_eq!(mb.images, img_b, "job B images");
    assert_eq!(mb.streamed_cycles, str_b, "job B streamed cycles");
    assert_eq!(mb.reconfig_write_cycles, rec_b, "job B reconfig writes");

    // Per-job rows partition the global counters.
    let snap = session.metrics().snapshot();
    assert_eq!(ma.images + mb.images, snap[1].1);
    assert_eq!(ma.streamed_cycles + mb.streamed_cycles, snap[2].1);
    assert_eq!(ma.reconfig_write_cycles + mb.reconfig_write_cycles, snap[3].1);
}

#[test]
fn concurrent_cp_als_jobs_match_isolated_runs_and_predictions() {
    // The acceptance shape: >= 2 full CP-ALS jobs interleave on one
    // coordinated session; every job's trajectory equals its isolated
    // run bit for bit, and its attributed cycles equal the predicted
    // census of (iters x nmodes) plan executions.
    let xs: Vec<DenseTensor> = vec![
        low_rank(41, &[22, 14, 10], 3, 0.02),
        low_rank(42, &[22, 14, 10], 3, 0.02), // same shape: namespaces matter
        low_rank(43, &[18, 16, 8], 2, 0.01),
    ];
    let cfgs: Vec<AlsConfig> = vec![
        AlsConfig { rank: 3, max_iters: 7, tol: 0.0, seed: 1 },
        AlsConfig { rank: 3, max_iters: 7, tol: 0.0, seed: 2 },
        AlsConfig { rank: 2, max_iters: 9, tol: 0.0, seed: 3 },
    ];

    let session = cpu_session(Engine::Coordinated { shards: 2 });
    let mut results = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, (x, cfg)) in xs.iter().zip(&cfgs).enumerate() {
            let job = session.job(JobId(i as u64 + 1));
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || {
                CpAls::new(cfg).run_job(&job, CpTarget::Dense(x)).unwrap()
            }));
        }
        for h in handles {
            results.push(h.join().unwrap());
        }
    });

    for (i, ((x, cfg), res)) in xs.iter().zip(&cfgs).zip(&results).enumerate() {
        // Isolated single-array rerun: must match bit for bit.
        let solo = cpu_session(Engine::SingleArray);
        let want = CpAls::new(cfg.clone()).run(&solo, CpTarget::Dense(x)).unwrap();
        assert_eq!(res.fit_history, want.fit_history, "job {i} trajectory");
        assert_eq!(res.lambda, want.lambda, "job {i} lambda");
        for (fa, fb) in res.factors.iter().zip(&want.factors) {
            assert_eq!(fa.data(), fb.data(), "job {i} factors");
        }

        // Cycle-exact per-job attribution: iters executions per mode.
        let job = session.job(JobId(i as u64 + 1));
        let kernels: Vec<Kernel<'_>> = (0..3)
            .map(|mode| Kernel::DenseMttkrp { x, factors: &res.factors, mode })
            .collect();
        let (img, streamed, reconfig) =
            predict_total(&job, &kernels, res.iters as u64);
        let m = job.metrics();
        assert_eq!(m.requests, 3 * res.iters as u64, "job {i} requests");
        assert_eq!(m.images, img, "job {i} images");
        assert_eq!(m.streamed_cycles, streamed, "job {i} streamed");
        assert_eq!(m.reconfig_write_cycles, reconfig, "job {i} reconfig");
    }
}

#[test]
fn sequential_same_shape_decompositions_do_not_reuse_stale_streams() {
    // Two decompositions of *different* tensors with identical shape and
    // rank, back to back on one session under the default job: every
    // dimension check passes, so without the namespace clear in
    // CpAls::run_job the second run would silently stream the first
    // tensor's quantized codes.  Each run must equal its isolated run.
    let x1 = low_rank(71, &[18, 12, 10], 3, 0.01);
    let x2 = low_rank(72, &[18, 12, 10], 3, 0.01);
    let cfg = AlsConfig { rank: 3, max_iters: 6, tol: 0.0, seed: 4 };

    let session = cpu_session(Engine::SingleArray);
    let r1 = CpAls::new(cfg.clone()).run(&session, CpTarget::Dense(&x1)).unwrap();
    let r2 = CpAls::new(cfg.clone()).run(&session, CpTarget::Dense(&x2)).unwrap();

    let w1 = CpAls::new(cfg.clone())
        .run(&cpu_session(Engine::SingleArray), CpTarget::Dense(&x1))
        .unwrap();
    let w2 = CpAls::new(cfg.clone())
        .run(&cpu_session(Engine::SingleArray), CpTarget::Dense(&x2))
        .unwrap();
    assert_eq!(r1.fit_history, w1.fit_history);
    assert_eq!(r2.fit_history, w2.fit_history, "second run reused stale streams");

    // Tucker too: same session, same shapes, different tensors.
    let hooi =
        TuckerHooi::new(TuckerConfig { ranks: vec![2, 2, 2], max_iters: 4, tol: 0.0 });
    let t1 = hooi.run(&x1, &session).unwrap();
    let t2 = hooi.run(&x2, &session).unwrap();
    let v1 = hooi.run(&x1, &cpu_session(Engine::SingleArray)).unwrap();
    let v2 = hooi.run(&x2, &cpu_session(Engine::SingleArray)).unwrap();
    assert_eq!(t1.fit_history, v1.fit_history);
    assert_eq!(t2.fit_history, v2.fit_history, "second HOOI reused stale streams");

    // And nothing accumulates: every driver run releases its namespace,
    // so a long-lived session does not retain per-job plan arenas.
    assert_eq!(session.cached_plans(), 0);
}

#[test]
fn job_namespaces_prevent_same_shape_cross_talk() {
    // Two jobs, two different tensors of identical shape, interleaved on
    // one session: every result must match the per-tensor reference.
    // (With a shared cache the second job would reuse the first job's
    // streamed codes — this is the aliasing the namespaces kill.)
    let mut rng = Prng::new(51);
    let x1 = DenseTensor::randn(&[14, 10, 8], &mut rng);
    let x2 = DenseTensor::randn(&[14, 10, 8], &mut rng);
    let factors: Vec<Matrix> =
        [14, 10, 8].iter().map(|&d| Matrix::randn(d, 4, &mut rng)).collect();

    let session = cpu_session(Engine::SingleArray);
    let j1 = session.job(JobId(1));
    let j2 = session.job(JobId(2));
    for _ in 0..2 {
        for mode in 0..3 {
            let a = j1
                .run(Kernel::DenseMttkrp { x: &x1, factors: &factors, mode })
                .unwrap();
            let b = j2
                .run(Kernel::DenseMttkrp { x: &x2, factors: &factors, mode })
                .unwrap();
            let mut exec = CpuTileExecutor::paper();
            let want1 = psram_imc::mttkrp::pipeline::PsramPipeline::new(&mut exec)
                .mttkrp(&x1, &factors, mode)
                .unwrap();
            let mut exec = CpuTileExecutor::paper();
            let want2 = psram_imc::mttkrp::pipeline::PsramPipeline::new(&mut exec)
                .mttkrp(&x2, &factors, mode)
                .unwrap();
            assert_eq!(a.data(), want1.data(), "job 1 mode {mode}");
            assert_eq!(b.data(), want2.data(), "job 2 mode {mode}");
        }
    }
    assert_eq!(session.cached_plans(), 6);
    session.clear_job(JobId(1));
    assert_eq!(session.cached_plans(), 3);
    session.clear_cache();
    assert_eq!(session.cached_plans(), 0);
}

#[test]
fn sparse_session_round_trip_matches_pipeline() {
    // The sparse kernel through a coordinated session stays bit-identical
    // to the single-array sparse pipeline (planner + pool contract).
    let mut rng = Prng::new(61);
    let x = CooTensor::random(&[30, 520, 12], 900, &mut rng);
    let factors: Vec<Matrix> =
        [30, 520, 12].iter().map(|&d| Matrix::randn(d, 24, &mut rng)).collect();
    let mut exec = CpuTileExecutor::paper();
    let want = SparsePsramPipeline::new(&mut exec).mttkrp(&x, &factors, 0).unwrap();
    let session = cpu_session(Engine::Coordinated { shards: 3 });
    let got = session
        .run(Kernel::SparseMttkrp { x: &x, factors: &factors, mode: 0 })
        .unwrap();
    assert_eq!(got.data(), want.data());

    // And predict is cycle-exact for the sparse kernel too (fresh job so
    // the snapshot covers exactly this one submission).
    let j = session.job(JobId(7));
    let k = Kernel::SparseMttkrp { x: &x, factors: &factors, mode: 1 };
    let est = j.predict(&k).unwrap();
    j.run(k).unwrap();
    let m = j.metrics();
    assert_eq!(est.images, m.images);
    assert_eq!(est.compute_cycles, m.streamed_cycles);
    assert_eq!(est.reconfig_write_cycles, m.reconfig_write_cycles);
}

#[test]
fn tuning_policy_is_bit_invisible_at_the_session_surface() {
    // Fixed tuning (any chunk size, any intra-shard width) and the
    // untuned defaults must produce identical bits and identical
    // measured cycle metrics on both pSRAM engines — tuning only moves
    // host wall-clock.
    use psram_imc::session::TunePolicy;
    use psram_imc::tune::TuneParams;
    let mut rng = Prng::new(71);
    let x = DenseTensor::randn(&[60, 9, 40], &mut rng);
    let factors: Vec<Matrix> =
        [60, 9, 40].iter().map(|&d| Matrix::randn(d, 20, &mut rng)).collect();
    let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };

    for engine in [Engine::SingleArray, Engine::Coordinated { shards: 2 }] {
        let baseline = PsramSession::builder()
            .engine(engine)
            .tuning(TunePolicy::Fixed(TuneParams::default()))
            .build()
            .unwrap();
        let want = baseline.run(k).unwrap();
        let want_m = baseline.job_metrics(JobId::DEFAULT);

        let tuned = PsramSession::builder()
            .engine(engine)
            .tuning(TunePolicy::Fixed(TuneParams {
                block_cycles: 19,
                intra_workers: 1,
            }))
            .intra_workers(2)
            .build()
            .unwrap();
        let got = tuned.run(k).unwrap();
        let got_m = tuned.job_metrics(JobId::DEFAULT);

        assert_eq!(got.data(), want.data(), "{engine:?}");
        assert_eq!(got_m.images, want_m.images, "{engine:?}");
        assert_eq!(got_m.streamed_cycles, want_m.streamed_cycles, "{engine:?}");
        assert_eq!(
            got_m.reconfig_write_cycles, want_m.reconfig_write_cycles,
            "{engine:?}"
        );
        assert_eq!(got_m.useful_macs, want_m.useful_macs, "{engine:?}");
        assert_eq!(got_m.raw_macs, want_m.raw_macs, "{engine:?}");
    }

    // The default Auto policy stays bit-identical too (it only picks
    // different wall-clock parameters).
    let auto = PsramSession::builder().build().unwrap();
    let fixed = PsramSession::builder()
        .tuning(TunePolicy::Fixed(TuneParams::default()))
        .build()
        .unwrap();
    assert_eq!(auto.run(k).unwrap().data(), fixed.run(k).unwrap().data());
}

// ---------------------------------------------------------------------------
// Shutdown under fault: broken pools fail fast with typed errors, shut
// down idempotently, and never hang or leak workers.
// ---------------------------------------------------------------------------

mod shutdown_under_fault {
    use psram_imc::coordinator::{Coordinator, CoordinatorConfig, RecoveryPolicy};
    use psram_imc::fault::{
        silence_injected_death_panics, Backoff, DeathMode, FaultEvent, FaultInjector,
        FaultKind, FaultPlan, FaultPolicy, FaultyExecutor,
    };
    use psram_imc::mttkrp::pipeline::CpuTileExecutor;
    use psram_imc::mttkrp::plan::{DensePlanner, TilePlan};
    use psram_imc::session::{Engine, JobId, Kernel, PsramSession};
    use psram_imc::tensor::{DenseTensor, Matrix};
    use psram_imc::util::prng::Prng;
    use psram_imc::Error;
    use std::sync::Arc;

    /// A one-worker pool whose only worker dies at its first image load,
    /// with no respawn budget — the smallest permanently broken pool.
    fn doomed_pool() -> Coordinator {
        silence_injected_death_panics();
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(
            3,
            vec![FaultEvent {
                worker: 0,
                load_idx: 0,
                kind: FaultKind::WorkerDeath,
            }],
        )));
        Coordinator::spawn(
            CoordinatorConfig {
                recovery: RecoveryPolicy {
                    respawn_budget: 0,
                    backoff: Backoff::none(),
                    ..RecoveryPolicy::default()
                },
                ..CoordinatorConfig::new(1)
            },
            move |i| {
                Ok(FaultyExecutor::new(
                    CpuTileExecutor::paper(),
                    Arc::clone(&inj),
                    i,
                    DeathMode::Panic,
                    &FaultPolicy::default(),
                ))
            },
        )
        .unwrap()
    }

    fn one_image_plan() -> TilePlan {
        let mut rng = Prng::new(17);
        let unf = Matrix::randn(20, 64, &mut rng);
        let krp = Matrix::randn(64, 8, &mut rng);
        DensePlanner::new(256, 32, 52).plan_unfolded(&unf, &krp).unwrap()
    }

    #[test]
    fn submit_after_worker_death_fails_fast_with_typed_error() {
        let plan = one_image_plan();
        let mut pool = doomed_pool();
        // The in-flight request gets the supervision context...
        let err = pool.execute_plan(&plan).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("respawn budget"), "{err}");
        assert!(pool.broken().is_some());
        // ...and every later submission fails fast instead of hanging on
        // a queue no live worker will ever drain.
        let err = pool.execute_plan(&plan).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("broken"), "{err}");
    }

    #[test]
    fn double_shutdown_while_workers_dead_is_clean() {
        let plan = one_image_plan();
        let mut pool = doomed_pool();
        let _ = pool.execute_plan(&plan).unwrap_err();
        // Shutdown of a broken pool joins the surviving threads; a second
        // shutdown is an idempotent no-op, and drop after both is clean.
        pool.shutdown();
        assert!(pool.is_shut());
        pool.shutdown();
        assert!(pool.is_shut());
        let err = pool.execute_plan(&plan).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        drop(pool);
    }

    #[test]
    fn drop_while_workers_dead_never_hangs() {
        let plan = one_image_plan();
        let mut pool = doomed_pool();
        let _ = pool.execute_plan(&plan).unwrap_err();
        // No explicit shutdown: Drop must still join without deadlocking
        // on the dead worker.
        drop(pool);
    }

    #[test]
    fn session_fails_fast_after_pool_breaks_unless_fallback_reroutes() {
        silence_injected_death_panics();
        let mut rng = Prng::new(18);
        let x = DenseTensor::randn(&[20, 8, 8], &mut rng);
        let factors: Vec<Matrix> =
            [20, 8, 8].iter().map(|&d| Matrix::randn(d, 8, &mut rng)).collect();
        let k = Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 };
        let death = || {
            Arc::new(FaultInjector::new(&FaultPlan::new(
                3,
                vec![FaultEvent {
                    worker: 0,
                    load_idx: 0,
                    kind: FaultKind::WorkerDeath,
                }],
            )))
        };

        // Strict policy: the first submission surfaces the supervision
        // error, the second fails fast on the broken pool — both typed.
        let strict = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 1 })
            .fault_injector(death())
            .fault_policy(FaultPolicy {
                respawn_budget: 0,
                retries: 0,
                backoff: Backoff::none(),
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let err = strict.run(k).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "{err}");
        assert!(err.to_string().contains("respawn budget"), "{err}");
        let err = strict.run(k).unwrap_err();
        assert!(err.to_string().contains("broken"), "{err}");

        // Degraded mode: the same schedule with `fallback` reroutes every
        // submission to the exact digital engine instead.
        let degraded = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 1 })
            .fault_injector(death())
            .fault_policy(FaultPolicy {
                respawn_budget: 0,
                retries: 0,
                backoff: Backoff::none(),
                fallback: true,
                ..FaultPolicy::default()
            })
            .build()
            .unwrap();
        let exact = k.run_exact().unwrap();
        assert_eq!(degraded.run(k).unwrap().data(), exact.data());
        assert_eq!(degraded.run(k).unwrap().data(), exact.data());
        let jm = degraded.job_metrics(JobId::DEFAULT);
        assert_eq!(jm.fallbacks, 2);
        assert_eq!(jm.requests, 2);
    }
}
