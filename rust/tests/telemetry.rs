//! Integration tests for the telemetry subsystem: JSON round-trip
//! properties, suite determinism (the property that makes a committed
//! baseline diffable at all), and the committed `BENCH_*.json` baselines
//! themselves — a fresh suite run must diff clean against them, and an
//! injected regression must gate.

use psram_imc::telemetry::json::Json;
use psram_imc::telemetry::suite::{self, AREAS};
use psram_imc::telemetry::{
    capture_env, diff, BenchEnv, BenchRecord, BenchReport, Direction, DiffStatus,
    MetricKind,
};
use psram_imc::util::proptest::{check_with, Config};
use std::path::Path;

fn test_env() -> BenchEnv {
    capture_env(Some("2026-08-07"))
}

/// Repo-root path of a committed baseline file.
fn baseline_path(area: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(suite::file_name(area))
}

// ---------------------------------------------------------------------------
// Satellite: JSON writer/parser round-trip property.
// ---------------------------------------------------------------------------

/// Arbitrary reports survive `to_json` → `from_json` unchanged: every
/// finite value (including subnormals, negative zero, and full-precision
/// irrationals), every direction/kind/tolerance combination, and string
/// fields that need escaping (quotes, backslashes, newlines, unicode).
#[test]
fn report_roundtrip_property() {
    check_with(
        "telemetry report JSON round-trip",
        Config { cases: 200, max_size: 24, ..Config::default() },
        |c| {
            let mut report = BenchReport::new(
                format!("suite-\"{}\"-\u{3bb}", c.index),
                test_env(),
            );
            let n_records = 1 + c.rng.below(c.size as u64 + 1);
            for k in 0..n_records {
                let value = match c.rng.below(6) {
                    0 => 0.0,
                    1 => c.rng.below(u64::MAX >> 11) as f64,
                    2 => -(c.rng.below(1_000_000) as f64),
                    3 => c.rng.normal() * 1e-300, // subnormal territory
                    4 => c.rng.uniform(),
                    _ => {
                        // random bit patterns cover the whole f64 space;
                        // keep only the finite ones (the writer rejects
                        // the rest by design, tested separately)
                        let v = f64::from_bits(c.rng.next_u64());
                        if v.is_finite() {
                            v
                        } else {
                            -0.0
                        }
                    }
                };
                let better = match c.rng.below(3) {
                    0 => Direction::Higher,
                    1 => Direction::Lower,
                    _ => Direction::Exact,
                };
                let mut rec = BenchRecord::new(
                    format!("m{k}.path\\with \"escapes\"\n\tand \u{1f389}"),
                    value,
                    ["ops/s", "cycles", "J", "ratio", "", "λ/s"]
                        [c.rng.below(6) as usize],
                )
                .better(better)
                .tol(c.rng.uniform())
                .samples(c.rng.below(1000));
                if c.rng.below(2) == 1 {
                    rec = rec.wall_clock();
                }
                report.push(rec).map_err(|e| e.to_string())?;
            }
            let text = report.to_json().map_err(|e| e.to_string())?;
            let back = BenchReport::from_json(&text).map_err(|e| e.to_string())?;
            if back != report {
                return Err(format!("round-trip mismatch:\n{text}"));
            }
            Ok(())
        },
    );
}

/// Non-finite values are rejected at every layer: pushing a record, the
/// JSON writer, and the parser (`NaN` tokens and overflowing literals).
#[test]
fn non_finite_rejected_at_every_layer() {
    let mut r = BenchReport::new("x", test_env());
    assert!(r.push(BenchRecord::new("a", f64::NAN, "")).is_err());
    assert!(r.push(BenchRecord::new("a", f64::INFINITY, "")).is_err());
    assert!(r.push(BenchRecord::new("a", f64::NEG_INFINITY, "")).is_err());
    assert!(r.records.is_empty());

    assert!(Json::Num(f64::NAN).to_string_pretty().is_err());
    for bad in ["NaN", "Infinity", "-Infinity", "1e999", "-1e999", "[1e400]"] {
        assert!(Json::parse(bad).is_err(), "parser accepted {bad:?}");
    }
}

/// A baseline written by a future (additive) schema still parses: unknown
/// fields at every level are ignored and missing optional fields take the
/// conservative defaults.
#[test]
fn future_schema_baselines_still_parse() {
    let text = r#"{
      "schema": 2,
      "suite": "headline",
      "generator": "vNEXT",
      "env": {"git_rev": "abc123", "hostname": "ci-7", "cpu_count": 64},
      "records": [
        {"name": "headline.peak_ops", "value": 1.704e16, "unit": "ops/s",
         "better": "higher", "rel_tol": 1e-6, "confidence_interval": [1, 2]},
        {"name": "future.metric", "value": -3.5}
      ]
    }"#;
    let r = BenchReport::from_json(text).unwrap();
    assert_eq!(r.schema, 2);
    assert_eq!(r.env.git_rev, "abc123");
    assert_eq!(r.env.cpu_count, 64);
    assert_eq!(r.value("headline.peak_ops"), Some(1.704e16));
    let fut = r.get("future.metric").unwrap();
    assert_eq!(fut.better, Direction::Exact);
    assert_eq!(fut.kind, MetricKind::Deterministic);
    assert_eq!(fut.rel_tol, 0.0);
    assert_eq!(fut.n, 1);
}

// ---------------------------------------------------------------------------
// Satellite: suite determinism — two back-to-back runs emit identical
// deterministic metrics (wall-clock records exempt).
// ---------------------------------------------------------------------------

#[test]
fn suite_deterministic_metrics_are_run_to_run_identical() {
    let env = test_env();
    for area in AREAS {
        let a = suite::run_area(area, &env).unwrap();
        let b = suite::run_area(area, &env).unwrap();
        assert_eq!(
            a.records.len(),
            b.records.len(),
            "area {area}: record count changed between runs"
        );
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.name, rb.name, "area {area}: record order changed");
            if ra.kind == MetricKind::Deterministic {
                assert_eq!(
                    ra.value.to_bits(),
                    rb.value.to_bits(),
                    "area {area}: {} drifted between identical runs \
                     ({} vs {})",
                    ra.name,
                    ra.value,
                    rb.value
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The committed baselines: every BENCH_*.json parses, carries provenance,
// and a fresh suite run diffs clean against it — the same gate CI runs.
// ---------------------------------------------------------------------------

#[test]
fn committed_baselines_parse_with_provenance() {
    for area in AREAS {
        let r = BenchReport::read_file(&baseline_path(area)).unwrap();
        assert_eq!(r.suite, area);
        assert_eq!(r.schema, psram_imc::telemetry::SCHEMA_VERSION);
        assert!(!r.records.is_empty(), "area {area}: empty baseline");
        assert_ne!(r.env.git_rev, "unknown", "area {area}: no provenance rev");
        assert_eq!(r.env.build_profile, "release");
        // committed baselines carry only gating records: wall-clock noise
        // from a live run classifies as `added` and never gates
        for rec in &r.records {
            assert_eq!(
                rec.kind,
                MetricKind::Deterministic,
                "area {area}: wall-clock record {} committed",
                rec.name
            );
        }
    }
}

#[test]
fn fresh_suite_run_diffs_clean_against_committed_baselines() {
    let env = test_env();
    for area in AREAS {
        let baseline = BenchReport::read_file(&baseline_path(area)).unwrap();
        let current = suite::run_area(area, &env).unwrap();
        let d = diff(&baseline, &current);
        assert!(
            !d.has_regressions(),
            "area {area} regressed vs committed baseline:\n{}",
            d.summary(true)
        );
        // every committed record is present in a live run (nothing Removed)
        for e in &d.entries {
            assert_ne!(
                e.status,
                DiffStatus::Removed,
                "area {area}: committed metric {} missing from a live run",
                e.name
            );
        }
    }
}

/// The gate actually gates: injecting a beyond-tolerance regression into a
/// fresh run (cycle-census drift, throughput loss, energy increase) must
/// trip `has_regressions`, and re-baselining (diffing the perturbed report
/// against itself) must clear it.
#[test]
fn injected_regressions_trip_the_gate() {
    let env = test_env();
    let baseline = BenchReport::read_file(&baseline_path("headline")).unwrap();
    let fresh = suite::run_area("headline", &env).unwrap();

    let perturb = |name: &str, factor: f64| {
        let mut bad = fresh.clone();
        let rec =
            bad.records.iter_mut().find(|r| r.name == name).unwrap_or_else(|| {
                panic!("suite no longer emits {name}")
            });
        rec.value *= factor;
        bad
    };

    // Exact cycle-census pin: any drift regresses, improvements included.
    for factor in [1.5, 0.5] {
        let bad = perturb("headline.scaled.measured_compute_cycles", factor);
        let d = diff(&baseline, &bad);
        assert!(d.has_regressions(), "census drift x{factor} not gated");
    }
    // Higher-is-better throughput: only the drop regresses.
    assert!(diff(&baseline, &perturb("headline.sustained_ops", 0.9))
        .has_regressions());
    assert!(!diff(&baseline, &perturb("headline.sustained_ops", 1.1))
        .has_regressions());
    // Lower-is-better energy: only the increase regresses.
    assert!(diff(&baseline, &perturb("headline.paper_energy_total_j", 1.1))
        .has_regressions());
    assert!(!diff(&baseline, &perturb("headline.paper_energy_total_j", 0.9))
        .has_regressions());
    // Within-tolerance noise does not gate (1e-6 relative on throughput).
    assert!(!diff(&baseline, &perturb("headline.sustained_ops", 1.0 - 1e-9))
        .has_regressions());

    // Re-baselining clears the gate: a report always diffs clean against
    // itself, perturbed or not.
    let bad = perturb("headline.peak_ops", 0.5);
    assert!(!diff(&bad, &bad).has_regressions());
}
