//! Integration suite for the admission-controlled service tier
//! (`psram_imc::service`).  The contracts under test, end to end:
//!
//! * **Bit-identity** — every job kind served through any pool mix must
//!   reproduce the serial single-session reference bit for bit.
//! * **Fairness** — under a backlogged window, weighted-fair dispatch
//!   shares track the configured weight ratios within tolerance.
//! * **Backpressure** — the bounded queue rejects deterministically at
//!   capacity and drains (re-admits) once pressure lifts.
//! * **Cancellation** — a cancel never leaks a worker, a queue slot, or
//!   a quota unit (counter-audited), queued or mid-run.
//! * **Chaos** — seeded worker deaths on a coordinated pool heal without
//!   violating per-tenant accounting (replay with `CHAOS_SEED=<u64>`).
//! * **Shutdown** — tearing the tier (or a shared session) down under
//!   concurrent load resolves every submission with `Done` or a typed
//!   error, watchdog-bounded: never a hang.

use psram_imc::fault::{
    silence_injected_death_panics, Backoff, FaultInjector, FaultPlan, FaultPolicy, FaultSpec,
};
use psram_imc::perfmodel::PerfModel;
use psram_imc::service::{
    simulate, CancelToken, Completion, JobSpec, PoolSpec, Reject, Scheduler, ServiceConfig,
    SimJob, TenantId, TenantSpec,
};
use psram_imc::session::{Engine, JobId, Kernel, PsramSession};
use psram_imc::tensor::{DenseTensor, Matrix};
use psram_imc::util::prng::Prng;
use psram_imc::Error;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// Bound on any single blocking wait: generous enough for a loaded CI
/// runner, small enough that a genuine hang fails the suite fast.
const WATCHDOG: Duration = Duration::from_secs(120);

/// The fixed seed matrix CI replays, overridable with `CHAOS_SEED=<u64>`
/// (same convention as `tests/chaos.rs`).
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => vec![s.trim().parse().expect("CHAOS_SEED must be a u64")],
        Err(_) => vec![11, 23, 47],
    }
}

/// A tier config of `n` tenants with the given weights and unbounded
/// quotas.
fn tier_cfg(bound: usize, weights: &[u32]) -> ServiceConfig {
    ServiceConfig {
        queue_bound: bound,
        tenants: weights
            .iter()
            .enumerate()
            .map(|(i, &w)| (TenantId(i as u32), TenantSpec { weight: w, quota: usize::MAX }))
            .collect(),
        default_tenant: TenantSpec::default(),
    }
}

fn small_spec(seed: u64) -> JobSpec {
    JobSpec::DenseMttkrp { shape: [20, 12, 8], rank: 5, mode: (seed % 3) as usize, seed }
}

/// (a) Every job kind, served through a heterogeneous pool mix (one
/// single-array pool + one 2-shard coordinated pool), is bit-identical
/// to the same spec replayed serially on a fresh session.
#[test]
fn every_job_kind_is_bit_identical_to_the_serial_reference() {
    let cfg = tier_cfg(32, &[2, 1]);
    let pools = [PoolSpec::single(), PoolSpec::coordinated(2)];
    let sched = Scheduler::new(&cfg, &pools, PerfModel::paper()).unwrap();
    let specs = vec![
        JobSpec::DenseMttkrp { shape: [20, 12, 8], rank: 5, mode: 0, seed: 11 },
        JobSpec::SparseMttkrp { shape: [48, 32, 16], nnz: 300, rank: 6, mode: 1, seed: 12 },
        JobSpec::Ttm { shape: [24, 16, 12], rank: 5, mode: 2, seed: 13 },
        JobSpec::CpAls { shape: [16, 12, 8], rank: 4, sweeps: 3, seed: 14 },
        JobSpec::Hooi { shape: [16, 12, 8], rank: 4, sweeps: 2, seed: 15 },
    ];
    let handles: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| sched.submit(TenantId((i % 2) as u32), s.clone()).unwrap())
        .collect();
    let outs: Vec<_> =
        handles.into_iter().map(|h| h.wait().into_result().unwrap()).collect();

    let serial = PsramSession::builder().build().unwrap();
    for (i, (spec, out)) in specs.iter().zip(&outs).enumerate() {
        let reference = spec
            .run(&serial.job(JobId(100 + i as u64)), &CancelToken::new())
            .unwrap();
        assert!(
            out.bits_eq(&reference),
            "{} diverged from its serial reference",
            spec.name()
        );
    }
    let c = sched.counters();
    assert_eq!((c.admitted, c.completed, c.failed), (5, 5, 0));
}

/// (b) Weighted-fair shares within tolerance over a virtual-time window:
/// weights 4:2:1 on mixed job sizes, every tenant backlogged through the
/// whole window, shares within 2 % (absolute) of the weight fractions.
#[test]
fn weighted_fair_window_shares_track_weights_within_tolerance() {
    let cfg = tier_cfg(4000, &[4, 2, 1]);
    let sizes = [600u64, 1000, 1400];
    let mut jobs = Vec::new();
    for i in 0..500usize {
        for t in 0..3usize {
            jobs.push(SimJob {
                at: 0,
                tenant: TenantId(t as u32),
                service: sizes[(i + t) % sizes.len()],
            });
        }
    }
    let window = 700_000u64;
    let r = simulate(&cfg, 1, &jobs, &[], window);

    let total: u64 = r.per_tenant.iter().map(|t| t.window_dispatched).sum();
    assert!(total > 0);
    let weight_sum: u32 = r.per_tenant.iter().map(|t| t.weight).sum();
    for t in &r.per_tenant {
        let share = t.window_dispatched as f64 / total as f64;
        let expected = f64::from(t.weight) / f64::from(weight_sum);
        assert!(
            (share - expected).abs() < 0.02,
            "{} share {share:.4} strays from weight fraction {expected:.4}",
            t.tenant
        );
        // The window closed while the tenant still had backlog — the
        // share above measured *scheduling*, not admission.
        assert!(t.window_dispatched < 500, "{} drained inside the window", t.tenant);
        assert_eq!(t.dispatched, 500, "{} lost jobs over the full run", t.tenant);
    }
    assert_eq!(r.counters.completed, 1500);
}

/// (c) The bounded queue rejects deterministically at capacity and
/// drains after backpressure lifts: rejected work is re-admitted and
/// completes.
#[test]
fn bounded_queue_rejects_at_capacity_then_drains() {
    let cfg = tier_cfg(3, &[1]);
    let sched = Scheduler::new(&cfg, &[PoolSpec::single()], PerfModel::paper()).unwrap();
    sched.pause();
    let admitted: Vec<_> =
        (0..3).map(|i| sched.submit(TenantId(0), small_spec(i)).unwrap()).collect();
    for i in 3..5 {
        assert!(
            matches!(
                sched.submit(TenantId(0), small_spec(i)),
                Err(Reject::QueueFull { bound: 3 })
            ),
            "submission {i} was not rejected at capacity"
        );
    }
    assert_eq!(sched.counters().rejected_full, 2);
    assert_eq!(sched.queued_len(), 3);

    sched.resume();
    for h in admitted {
        assert!(h.wait().is_done());
    }
    // Pressure lifted: the formerly rejected submissions are admitted
    // now and run to completion.
    for i in 3..5 {
        assert!(sched.submit(TenantId(0), small_spec(i)).unwrap().wait().is_done());
    }
    let c = sched.counters();
    assert_eq!((c.admitted, c.completed), (5, 5));
    assert_eq!(sched.queued_len() + sched.in_flight(), 0);
}

/// (d) Cancellation never leaks a worker or a queue slot: queued cancels
/// release their slots immediately, a mid-run cooperative cancel stops
/// at the next kernel boundary, and afterwards the admission ledger
/// balances exactly (admitted == terminal, nothing queued or in flight)
/// while the tier keeps serving.
#[test]
fn cancellation_never_leaks_a_worker_or_queue_slot() {
    let cfg = tier_cfg(8, &[1, 1]);
    let sched = Scheduler::new(&cfg, &[PoolSpec::single()], PerfModel::paper()).unwrap();

    // Queued cancels under pause: slots and quota free up before resume.
    sched.pause();
    let handles: Vec<_> =
        (0..4).map(|i| sched.submit(TenantId(0), small_spec(i)).unwrap()).collect();
    handles[1].cancel();
    handles[2].cancel();
    assert_eq!(sched.queued_len(), 2, "queued cancels must free their slots eagerly");
    sched.resume();
    let (mut done, mut cancelled) = (0u32, 0u32);
    for h in handles {
        match h.wait() {
            Completion::Done(_) => done += 1,
            Completion::Cancelled => cancelled += 1,
            Completion::Failed(e) => panic!("unexpected failure: {e}"),
        }
    }
    assert_eq!((done, cancelled), (2, 2));

    // Mid-run cooperative cancel: a long iterative job observes the
    // token at a kernel boundary.  Resolution is watchdog-bounded — a
    // leaked runner or slot would hang the wait, not just fail it.
    let completed_before = sched.counters().completed;
    let long = JobSpec::CpAls { shape: [32, 24, 16], rank: 6, sweeps: 150, seed: 9 };
    let h = sched.submit(TenantId(1), long).unwrap();
    loop {
        if sched.in_flight() > 0 || sched.counters().completed > completed_before {
            break;
        }
        thread::yield_now();
    }
    h.cancel();
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let _ = tx.send(h.wait());
    });
    let completion = rx.recv_timeout(WATCHDOG).expect("cancelled job never resolved");
    assert!(
        !matches!(completion, Completion::Failed(_)),
        "cancel surfaced as a failure instead of Cancelled/Done"
    );

    // The audit: every admitted job reached a terminal state, nothing
    // occupies a slot, and the (sole) worker still serves new work.
    let c = sched.counters();
    assert_eq!(c.admitted, c.terminal(), "admission ledger out of balance");
    assert_eq!(sched.queued_len(), 0);
    assert_eq!(sched.in_flight(), 0);
    assert_eq!(sched.outstanding(TenantId(0)) + sched.outstanding(TenantId(1)), 0);
    assert!(sched.submit(TenantId(0), small_spec(99)).unwrap().wait().is_done());
}

/// (e) Chaos composition: seeded worker deaths (plus a transient) on a
/// coordinated pool heal — or fail typed — without ever violating the
/// per-tenant admission accounting or the bit-identity contract.
#[test]
fn chaos_worker_deaths_heal_without_breaking_tenant_accounting() {
    silence_injected_death_panics();
    for seed in chaos_seeds() {
        let spec = FaultSpec {
            workers: 2,
            horizon_loads: 24,
            upsets: 0,
            upset_bits: 4,
            transients: 1,
            deaths: 2,
        };
        let inj = Arc::new(FaultInjector::new(&FaultPlan::from_seed(seed, &spec)));
        let pool = PoolSpec::coordinated(2)
            .fault_injector(Arc::clone(&inj))
            .fault_policy(FaultPolicy {
                retries: 4,
                backoff: Backoff::none(),
                respawn_budget: 4,
                ..FaultPolicy::default()
            });
        let cfg = tier_cfg(16, &[2, 1]);
        let sched = Scheduler::new(&cfg, &[pool], PerfModel::paper()).unwrap();

        let serial = PsramSession::builder().build().unwrap();
        let mut handles = Vec::new();
        for tenant in 0..2u32 {
            for i in 0..4u64 {
                let s = small_spec(u64::from(tenant) * 10 + i);
                handles.push((tenant, s.clone(), sched.submit(TenantId(tenant), s).unwrap()));
            }
        }
        for (tenant, s, h) in handles {
            match h.wait() {
                Completion::Done(out) => {
                    let reference = s
                        .run(&serial.job(JobId(500 + u64::from(tenant))), &CancelToken::new())
                        .unwrap();
                    assert!(
                        out.bits_eq(&reference),
                        "seed {seed}: corrupted result escaped recovery ({})",
                        s.name()
                    );
                }
                Completion::Failed(e) => assert!(
                    matches!(e, Error::Fault(_) | Error::Coordinator(_)),
                    "seed {seed}: untyped failure {e}"
                ),
                Completion::Cancelled => panic!("seed {seed}: nothing was cancelled"),
            }
        }
        let c = sched.counters();
        assert_eq!(c.admitted, 8);
        assert_eq!(c.admitted, c.terminal(), "seed {seed}: accounting violated");
        assert_eq!(c.completed + c.failed, 8);
        assert_eq!(
            sched.dispatched_of(TenantId(0)) + sched.dispatched_of(TenantId(1)),
            c.dispatched
        );
        for t in 0..2u32 {
            assert_eq!(sched.outstanding(TenantId(t)), 0, "seed {seed}: tenant{t} leaked");
        }
    }
}

/// The PR-8 review fix, pinned: `Coordinator::try_submit` observes the
/// shutdown flag under the queue lock, so a submission racing
/// `PsramSession::shutdown` gets a typed fail-fast error instead of
/// enqueueing a batch no worker will answer and hanging in `recv()`.
/// N threads hammer a shared coordinated session while it is shut down
/// mid-flight; a watchdog bounds every outcome.
#[test]
fn shutdown_race_fails_fast() {
    let mut rng = Prng::new(77);
    let x = Arc::new(DenseTensor::randn(&[20, 8, 8], &mut rng));
    let factors: Arc<Vec<Matrix>> =
        Arc::new([20, 8, 8].iter().map(|&d| Matrix::randn(d, 8, &mut rng)).collect());
    let reference = {
        let clean = PsramSession::builder().build().unwrap();
        clean
            .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 })
            .unwrap()
    };

    // Several rounds to move the shutdown point around relative to the
    // submission stream (thread scheduling supplies the jitter).
    for round in 0..6u32 {
        let session = PsramSession::builder()
            .engine(Engine::Coordinated { shards: 2 })
            .build()
            .unwrap();
        let threads = 4usize;
        let per_thread = 6usize;
        let (tx, rx) = mpsc::channel();
        let mut joins = Vec::new();
        for t in 0..threads {
            let s = session.clone();
            let tx = tx.clone();
            let x = Arc::clone(&x);
            let factors = Arc::clone(&factors);
            joins.push(thread::spawn(move || {
                for i in 0..per_thread {
                    let r = s
                        .job(JobId((t * per_thread + i) as u64 + 1))
                        .run(Kernel::DenseMttkrp { x: &x, factors: &factors, mode: 0 })
                        .map(|m| m.data().to_vec());
                    tx.send(r).expect("collector vanished");
                }
            }));
        }
        drop(tx);
        // Let some submissions through, then pull the rug.
        thread::sleep(Duration::from_micros(u64::from(round) * 300));
        session.shutdown();
        assert!(session.is_shut());

        // Every submission resolves: bit-exact output or a typed
        // fail-fast error.  recv_timeout is the watchdog — the pre-fix
        // race left a leader blocked forever right here.
        for k in 0..threads * per_thread {
            let outcome = rx
                .recv_timeout(WATCHDOG)
                .unwrap_or_else(|_| panic!("round {round}: submission {k} hung"));
            match outcome {
                Ok(data) => assert_eq!(
                    data,
                    reference.data(),
                    "round {round}: submission survived shutdown with wrong bits"
                ),
                Err(e) => assert!(
                    matches!(e, Error::Fault(_) | Error::Coordinator(_)),
                    "round {round}: untyped shutdown error {e}"
                ),
            }
        }
        for j in joins {
            j.join().expect("submitter panicked");
        }
    }
}

/// Scheduler-level shutdown under load: queued jobs fail fast with a
/// typed `Error::Service`, in-flight jobs finish, later submissions are
/// rejected `ShutDown`, and every handle resolves inside the watchdog.
#[test]
fn scheduler_shutdown_under_load_resolves_every_handle() {
    let cfg = tier_cfg(32, &[1, 1, 1]);
    let pools = [PoolSpec::single(), PoolSpec::coordinated(2)];
    let mut sched = Scheduler::new(&cfg, &pools, PerfModel::paper()).unwrap();

    let mut handles = Vec::new();
    for i in 0..12u64 {
        let spec = if i % 4 == 0 {
            JobSpec::CpAls { shape: [24, 16, 12], rank: 4, sweeps: 20, seed: i }
        } else {
            small_spec(i)
        };
        handles.push(sched.submit(TenantId((i % 3) as u32), spec).unwrap());
    }
    let (tx, rx) = mpsc::channel();
    let waiter = thread::spawn(move || {
        for h in handles {
            tx.send(h.wait()).expect("collector vanished");
        }
    });
    // Shut down while the backlog is still draining (or, if the runners
    // outran us, after everything already finished — both legal).
    loop {
        if sched.in_flight() > 0 || sched.counters().terminal() >= 12 {
            break;
        }
        thread::yield_now();
    }
    sched.shutdown();

    let (mut done, mut failed) = (0u64, 0u64);
    for k in 0..12 {
        match rx.recv_timeout(WATCHDOG).unwrap_or_else(|_| panic!("handle {k} hung")) {
            Completion::Done(_) => done += 1,
            Completion::Failed(Error::Service(_)) => failed += 1,
            Completion::Failed(e) => panic!("untyped shutdown failure: {e}"),
            Completion::Cancelled => panic!("nothing was cancelled"),
        }
    }
    waiter.join().unwrap();
    assert_eq!(done + failed, 12);
    assert!(matches!(sched.submit(TenantId(0), small_spec(1)), Err(Reject::ShutDown)));
    let c = sched.counters();
    assert_eq!(c.admitted, c.terminal());
    assert_eq!(c.rejected_shutdown, 1);
    assert_eq!(sched.queued_len() + sched.in_flight(), 0);
}
