//! Property-based invariants over the pipeline, schedule and coordinator,
//! using the in-crate harness (`util::proptest`).  No artifacts needed.

use psram_imc::compute::{ComputeEngine, InterleavePattern};
use psram_imc::coordinator::{Coordinator, CoordinatorConfig};
use psram_imc::device::{Adc, DeviceParams, NoiseModel};
use psram_imc::mttkrp::pipeline::{
    AnalogTileExecutor, CpuTileExecutor, PsramPipeline, TileExecutor,
};
use psram_imc::mttkrp::plan::{execute_plan, DensePlanner, SparseSlicePlanner, TtmPlanner};
use psram_imc::mttkrp::reference::dense_mttkrp;
use psram_imc::mttkrp::{MttkrpStats, SparsePsramPipeline};
use psram_imc::perfmodel::{PerfModel, Workload};
use psram_imc::psram::{ArrayGeometry, PsramArray};
use psram_imc::service::{
    Outcome, SchedCore, ServiceConfig, TenantId, TenantSpec, Ticket, TrafficConfig,
};
use psram_imc::tensor::{krp_all_but, CooTensor, DenseTensor, Matrix};
use psram_imc::util::fixed::{encode_offset, quant_matmul_ref};
use psram_imc::util::proptest::{check, check_with, Case, Config};
use psram_imc::{prop_assert, prop_assert_eq};

fn rand_shape(c: &mut Case, max_dim: usize) -> Vec<usize> {
    (0..3).map(|_| 1 + c.rng.below(max_dim as u64) as usize).collect()
}

#[test]
fn prop_pipeline_matches_reference_within_quant_bound() {
    check_with(
        "pipeline ≈ exact MTTKRP",
        Config { cases: 30, max_size: 24, seed: 0xA1 },
        |c| {
            let shape = rand_shape(c, 4 + c.size);
            let r = 1 + c.rng.below(10) as usize;
            let mode = c.rng.below(3) as usize;
            let x = DenseTensor::randn(&shape, &mut c.rng);
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, &mut c.rng)).collect();

            let mut exec = CpuTileExecutor::paper();
            let approx =
                PsramPipeline::new(&mut exec).mttkrp(&x, &factors, mode).unwrap();
            let exact = dense_mttkrp(&x, &factors, mode).unwrap();

            let unf = x.unfold(mode).unwrap();
            let krp = krp_all_but(&factors, mode).unwrap();
            let k = unf.cols() as f32;
            let sx = unf.max_abs() / 127.0;
            let sw = krp.max_abs() / 127.0;
            let bound =
                (k * (sx * krp.max_abs() / 2.0 + sw * unf.max_abs() / 2.0 + sx * sw / 4.0))
                    .max(1e-4);
            for (e, a) in exact.data().iter().zip(approx.data()) {
                prop_assert!(
                    (e - a).abs() <= bound,
                    "err {} > bound {bound} (shape {shape:?} r {r} mode {mode})",
                    (e - a).abs()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coordinator_equals_pipeline_bit_exactly() {
    check_with(
        "coordinator == single pipeline",
        Config { cases: 15, max_size: 20, seed: 0xB2 },
        |c| {
            let shape = rand_shape(c, 6 + c.size);
            let r = 1 + c.rng.below(40) as usize;
            let x = DenseTensor::randn(&shape, &mut c.rng);
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, &mut c.rng)).collect();
            let workers = 1 + c.rng.below(4) as usize;

            let mut exec = CpuTileExecutor::paper();
            let single = PsramPipeline::new(&mut exec).mttkrp(&x, &factors, 0).unwrap();

            let mut pool = Coordinator::spawn(
                CoordinatorConfig { workers, queue_depth: 2, ..Default::default() },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            let dist = pool.mttkrp(&x, &factors, 0).unwrap();
            prop_assert!(
                single.data() == dist.data(),
                "distributed result diverged (workers {workers})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_quant_matmul_bitplane_identity() {
    // The fixed-point contract: (u-128)@w computed via bit-planes with the
    // signed MSB weight always equals direct integer matmul.
    check("bit-plane identity", |c| {
        let m = 1 + c.rng.below(8) as usize;
        let k = 1 + c.rng.below(64) as usize;
        let n = 1 + c.rng.below(8) as usize;
        let u: Vec<u8> = (0..m * k).map(|_| c.rng.next_u8()).collect();
        let w: Vec<i8> = (0..k * n).map(|_| c.rng.next_i8()).collect();
        let direct = quant_matmul_ref(&u, &w, m, k, n);
        // bit-plane route
        let mut planes = vec![0i64; m * n];
        for b in 0..8u32 {
            let weight = psram_imc::util::fixed::plane_weight(b) as i64;
            for i in 0..m {
                for p in 0..k {
                    let bit = ((w[p * n] as u8) >> b) & 1; // recompute per column below
                    let _ = bit;
                    for j in 0..n {
                        let wb = ((w[p * n + j] as u8 as u32) >> b) & 1;
                        planes[i * n + j] +=
                            weight * wb as i64 * u[i * k + p] as i64;
                    }
                }
            }
        }
        let corr: Vec<i64> = (0..n)
            .map(|j| 128 * (0..k).map(|p| w[p * n + j] as i64).sum::<i64>())
            .collect();
        for i in 0..m {
            for j in 0..n {
                let v = planes[i * n + j] - corr[j];
                prop_assert_eq!(v as i32, direct[i * n + j]);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_analytic_model_matches_measured_pipeline_cycles() {
    // The perf model's cycle formulas must agree exactly with what the
    // functional pipeline measures, for any workload shape.
    check_with(
        "perfmodel == pipeline stats",
        Config { cases: 25, max_size: 30, seed: 0xC3 },
        |c| {
            let i = 1 + c.rng.below(120) as u64;
            let j = 1 + c.rng.below(12) as usize;
            let k = 1 + c.rng.below(40) as usize;
            let r = 1 + c.rng.below(48) as u64;
            let x = DenseTensor::randn(&[i as usize, j, k], &mut c.rng);
            let factors: Vec<Matrix> = [i as usize, j, k]
                .iter()
                .map(|&d| Matrix::randn(d, r as usize, &mut c.rng))
                .collect();
            let mut exec = CpuTileExecutor::paper();
            let mut pipe = PsramPipeline::new(&mut exec);
            pipe.mttkrp(&x, &factors, 0).unwrap();

            let model = PerfModel::paper();
            let est = model
                .predict(&Workload {
                    i_rows: i,
                    k_contraction: (j * k) as u64,
                    rank: r,
                })
                .unwrap();
            prop_assert_eq!(est.images, pipe.stats.images);
            prop_assert_eq!(est.compute_cycles, pipe.stats.compute_cycles);
            prop_assert_eq!(est.write_cycles, pipe.stats.write_cycles);
            let diff = (est.utilization - pipe.stats.utilization()).abs();
            prop_assert!(diff < 1e-12, "utilization diverged by {diff}");
            Ok(())
        },
    );
}

#[test]
fn prop_interleave_diagonal_never_mixes_products() {
    check_with(
        "CP1 interleave isolation",
        Config { cases: 20, max_size: 40, seed: 0xD4 },
        |c| {
            let r = 1 + c.rng.below(52.min(1 + c.size as u64)) as usize;
            let b: Vec<i8> = (0..r).map(|_| c.rng.next_i8()).collect();
            let cc: Vec<i8> = (0..r).map(|_| c.rng.next_i8()).collect();
            let mut eng = ComputeEngine::ideal();
            let mut array = PsramArray::paper();
            let out =
                psram_imc::mttkrp::mapping::cp1_hadamard(&mut eng, &mut array, &b, &cc)
                    .unwrap();
            for i in 0..r {
                prop_assert_eq!(out[i], b[i] as i32 * cc[i] as i32);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_interleave_pattern_invariant() {
    check("diagonal patterns are interleaved", |c| {
        let n = 1 + c.rng.below(52) as usize;
        let vals: Vec<i32> = (0..n).map(|_| c.rng.range_i64(-128, 127) as i32).collect();
        let p = InterleavePattern::diagonal(&vals, 256).unwrap();
        prop_assert!(p.is_interleaved(), "diagonal must be interleaved");
        let u = p.render();
        // exactly n non-zero codes
        let nonzero = u.iter().filter(|&&x| x != encode_offset(0)).count();
        let expected = vals.iter().filter(|&&v| v != 0).count();
        prop_assert_eq!(nonzero, expected);
        Ok(())
    });
}

#[test]
fn prop_tile_plan_occupancy_and_geometry_bounded() {
    // Any plan the planners emit must fit the physical envelope: lane
    // occupancy never exceeds the comb's channel capacity, stored images
    // never exceed the array geometry, and every accumulation target is a
    // real output row.  `predict_plan` must agree with the plan's own
    // cycle census.
    check_with(
        "plan within comb + array limits",
        Config { cases: 20, max_size: 20, seed: 0xF7 },
        |c| {
            let params = DeviceParams::default();
            let lanes = params.comb.max_channels();
            let geom = ArrayGeometry::PAPER;
            let (rows, wpr) = (geom.rows, geom.words_per_row());

            let shape = rand_shape(c, 6 + c.size);
            let r = 1 + c.rng.below(48) as usize;
            let mode = c.rng.below(3) as usize;
            let x = DenseTensor::randn(&shape, &mut c.rng);
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, &mut c.rng)).collect();
            let dense_plan = DensePlanner::new(rows, wpr, lanes)
                .plan_mttkrp(&x, &factors, mode)
                .map_err(|e| e.to_string())?;

            let nnz = c.rng.below(150) as usize;
            let coo = CooTensor::random(&shape, nnz, &mut c.rng);
            let sparse_plan = SparseSlicePlanner::new(rows, wpr, lanes)
                .plan(&coo, &factors, mode)
                .map_err(|e| e.to_string())?;

            for plan in [&dense_plan, &sparse_plan] {
                plan.validate().map_err(|e| e.to_string())?;
                prop_assert!(
                    plan.max_lane_occupancy() <= lanes,
                    "occupancy {} exceeds comb capacity {lanes}",
                    plan.max_lane_occupancy()
                );
                for g in &plan.groups {
                    for img in &g.images {
                        prop_assert_eq!(
                            img.words(&plan.arena, rows * wpr).len(),
                            rows * wpr
                        );
                        prop_assert!(
                            img.r_cnt <= wpr && img.r0 + img.r_cnt <= plan.out_cols,
                            "rank block [{}, {}) outside geometry/output",
                            img.r0,
                            img.r0 + img.r_cnt
                        );
                    }
                    for s in &g.streams {
                        prop_assert_eq!(
                            s.codes_in(&plan.arena, rows).len(),
                            s.lanes() * rows
                        );
                        prop_assert!(
                            s.targets_in(&plan.shape)
                                .iter()
                                .all(|&t| (t as usize) < plan.out_rows),
                            "accumulation target out of range"
                        );
                    }
                }
                let est = PerfModel::paper()
                    .predict_plan(plan)
                    .map_err(|e| e.to_string())?;
                prop_assert_eq!(est.images, plan.total_images() as u64);
                prop_assert_eq!(est.compute_cycles, plan.total_compute_cycles());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ttm_plan_execution_matches_nmode_reference() {
    // A TTM tile plan executed on the integer executor must approximate
    // the exact n-mode product within the analytic int8 bound, for random
    // tensor shapes, modes, ranks, and tile geometries — and noisy analog
    // twins with identical seeds must execute the same plan
    // bit-identically (deterministic noise streams).
    check_with(
        "ttm plan ≈ exact n-mode product",
        Config { cases: 20, max_size: 16, seed: 0x7A11 },
        |c| {
            let shape = rand_shape(c, 6 + c.size);
            let mode = c.rng.below(3) as usize;
            let r = 1 + c.rng.below(10) as usize;
            let x = DenseTensor::randn(&shape, &mut c.rng);
            let u = Matrix::randn(shape[mode], r, &mut c.rng);

            let rows = [64usize, 128, 256][c.rng.below(3) as usize];
            let wpr = [16usize, 32][c.rng.below(2) as usize];
            let lanes = 1 + c.rng.below(52) as usize;

            let plan = TtmPlanner::new(rows, wpr, lanes)
                .plan_ttm(&x, &u, mode)
                .map_err(|e| e.to_string())?;
            let mut exec = CpuTileExecutor::new(rows, wpr, lanes);
            let mut stats = MttkrpStats::default();
            let approx =
                execute_plan(&mut exec, &plan, &mut stats).map_err(|e| e.to_string())?;

            let exact = x.nmode_product(&u.transpose(), mode).unwrap();
            let exact_t = exact.unfold(mode).unwrap().transpose();
            let xt = x.unfold(mode).unwrap().transpose();
            let k = xt.cols() as f32;
            let sx = xt.max_abs() / 127.0;
            let sw = u.max_abs() / 127.0;
            let bound = (k
                * (sx * u.max_abs() / 2.0 + sw * xt.max_abs() / 2.0 + sx * sw / 4.0))
                .max(1e-4);
            for (e, a) in exact_t.data().iter().zip(approx.data()) {
                prop_assert!(
                    (e - a).abs() <= bound,
                    "err {} > bound {bound} (shape {shape:?} mode {mode} r {r} \
                     geom {rows}x{wpr}x{lanes})",
                    (e - a).abs()
                );
            }

            // Noise mode (paper geometry only — the analog array is fixed
            // at 256x32): identically seeded noisy twins agree bit for bit.
            if rows == 256 && wpr == 32 {
                let make = || {
                    AnalogTileExecutor::new(
                        ComputeEngine::new(
                            DeviceParams::default(),
                            NoiseModel::gaussian(25.0, 99),
                        ),
                        PsramArray::paper(),
                    )
                };
                let mut e1 = make();
                let mut s1 = MttkrpStats::default();
                let a = execute_plan(&mut e1, &plan, &mut s1).map_err(|e| e.to_string())?;
                let mut e2 = make();
                let mut s2 = MttkrpStats::default();
                let b = execute_plan(&mut e2, &plan, &mut s2).map_err(|e| e.to_string())?;
                prop_assert!(a.data() == b.data(), "noisy analog twins diverged");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ttm_coordinator_equals_single_executor_bit_exactly() {
    check_with(
        "ttm coordinator == single executor",
        Config { cases: 10, max_size: 16, seed: 0xF9A7 },
        |c| {
            let shape = rand_shape(c, 10);
            let mode = c.rng.below(3) as usize;
            let r = 1 + c.rng.below(40) as usize;
            let x = DenseTensor::randn(&shape, &mut c.rng);
            let u = Matrix::randn(shape[mode], r, &mut c.rng);
            let workers = 1 + c.rng.below(4) as usize;

            let plan = TtmPlanner::new(256, 32, 52)
                .plan_ttm(&x, &u, mode)
                .map_err(|e| e.to_string())?;
            let mut exec = CpuTileExecutor::paper();
            let mut stats = MttkrpStats::default();
            let single =
                execute_plan(&mut exec, &plan, &mut stats).map_err(|e| e.to_string())?;

            let mut pool = Coordinator::spawn(
                CoordinatorConfig { workers, queue_depth: 2, ..Default::default() },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            let dist = pool.execute_plan(&plan).map_err(|e| e.to_string())?;
            prop_assert!(
                single.data() == dist.data(),
                "ttm distributed result diverged (workers {workers} mode {mode})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_coordinator_equals_sparse_pipeline_bit_exactly() {
    check_with(
        "sparse coordinator == single sparse pipeline",
        Config { cases: 10, max_size: 16, seed: 0xF8 },
        |c| {
            let shape = rand_shape(c, 12);
            let nnz = c.rng.below(200) as usize;
            let coo = CooTensor::random(&shape, nnz, &mut c.rng);
            let r = 1 + c.rng.below(40) as usize;
            let mode = c.rng.below(3) as usize;
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, &mut c.rng)).collect();
            let workers = 1 + c.rng.below(4) as usize;

            let mut exec = CpuTileExecutor::paper();
            let single = SparsePsramPipeline::new(&mut exec)
                .mttkrp(&coo, &factors, mode)
                .unwrap();

            let mut pool = Coordinator::spawn(
                CoordinatorConfig { workers, queue_depth: 2, ..Default::default() },
                |_| Ok(CpuTileExecutor::paper()),
            )
            .unwrap();
            let dist = pool.sparse_mttkrp(&coo, &factors, mode).unwrap();
            prop_assert!(
                single.data() == dist.data(),
                "sparse distributed result diverged (workers {workers} mode {mode})"
            );
            Ok(())
        },
    );
}

/// Stream `lane_counts` cycles through `compute_block_into` and through
/// per-cycle `compute_into` on an identically prepared twin; both the
/// results and the compute-cycle ledgers must agree bit-exactly.
fn assert_block_equals_cycles<E: TileExecutor>(
    block_exec: &mut E,
    cycle_exec: &mut E,
    u: &[u8],
    lane_counts: &[usize],
) -> Result<(), String> {
    let rows = block_exec.rows();
    let wpr = block_exec.words_per_row();
    let total: usize = lane_counts.iter().sum();
    let mut block_out = vec![0i32; total * wpr];
    block_exec
        .compute_block_into(u, lane_counts, &mut block_out)
        .map_err(|e| e.to_string())?;
    let (mut co, mut oo) = (0usize, 0usize);
    for &lanes in lane_counts {
        let cycle = cycle_exec
            .compute(&u[co..co + lanes * rows], lanes)
            .map_err(|e| e.to_string())?;
        if block_out[oo..oo + lanes * wpr] != cycle[..] {
            return Err("block result diverged from per-cycle result".to_string());
        }
        co += lanes * rows;
        oo += lanes * wpr;
    }
    if block_exec.cycles().compute != cycle_exec.cycles().compute {
        return Err("block path charged different compute cycles".to_string());
    }
    Ok(())
}

#[test]
fn prop_compute_into_bit_identical_to_compute() {
    // The allocation-free entry points (`compute_cycle_into`,
    // `compute_into`, `compute_block_into`) must be bit-identical to the
    // allocating paths across random geometries, lane counts, and noise
    // modes (exact, Gaussian detector noise, coarse ADC).
    check_with(
        "compute_into == compute",
        Config { cases: 20, max_size: 16, seed: 0xF9 },
        |c| {
            let rows = [32usize, 64, 128, 256][c.rng.below(4) as usize];
            let cols = [64usize, 128, 256][c.rng.below(3) as usize];
            let geom = ArrayGeometry::new(rows, cols, 8).map_err(|e| e.to_string())?;
            let wpr = geom.words_per_row();
            let lanes = 1 + c.rng.below(52) as usize;
            let img: Vec<i8> =
                (0..geom.total_words()).map(|_| c.rng.next_i8()).collect();
            let u: Vec<u8> = (0..lanes * rows).map(|_| c.rng.next_u8()).collect();

            // Noise mode: exact fast path, Gaussian noise, or coarse ADC
            // (the latter two exercise the faithful path + colsum scratch).
            let pick = c.rng.below(3);
            let make_engine = || {
                let mut params = DeviceParams::default();
                match pick {
                    0 => ComputeEngine::new(params, NoiseModel::Off),
                    1 => ComputeEngine::new(params, NoiseModel::gaussian(50.0, 7)),
                    _ => {
                        params.adc = Adc::sar(10, f64::INFINITY);
                        ComputeEngine::new(params, NoiseModel::Off)
                    }
                }
            };

            // Engine level: compute_cycle vs compute_cycle_into on twins.
            let mut a1 = PsramArray::new(geom).map_err(|e| e.to_string())?;
            a1.write_image(&img).map_err(|e| e.to_string())?;
            let mut a2 = a1.clone();
            let mut e1 = make_engine();
            let mut e2 = make_engine();
            let alloc =
                e1.compute_cycle(&mut a1, &u, lanes).map_err(|e| e.to_string())?;
            let mut out = vec![i32::MAX; lanes * wpr];
            e2.compute_cycle_into(&mut a2, &u, lanes, &mut out)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                alloc == out,
                "engine into-path diverged (rows {rows} wpr {wpr} lanes {lanes} \
                 mode {pick})"
            );
            prop_assert!(a1.cycles.compute == a2.cycles.compute);

            // Executor level on the paper tile: block call == per-cycle
            // calls, for the CPU integer and the analog executor.
            let paper_img: Vec<i8> = (0..256 * 32).map(|_| c.rng.next_i8()).collect();
            let mut lane_counts = Vec::new();
            for _ in 0..1 + c.rng.below(4) {
                lane_counts.push(1 + c.rng.below(52) as usize);
            }
            let total: usize = lane_counts.iter().sum();
            let codes: Vec<u8> = (0..total * 256).map(|_| c.rng.next_u8()).collect();

            let mut cpu_a = CpuTileExecutor::paper();
            let mut cpu_b = CpuTileExecutor::paper();
            cpu_a.load_image(&paper_img).map_err(|e| e.to_string())?;
            cpu_b.load_image(&paper_img).map_err(|e| e.to_string())?;
            assert_block_equals_cycles(&mut cpu_a, &mut cpu_b, &codes, &lane_counts)?;

            let mut an_a = AnalogTileExecutor::ideal();
            let mut an_b = AnalogTileExecutor::ideal();
            an_a.load_image(&paper_img).map_err(|e| e.to_string())?;
            an_b.load_image(&paper_img).map_err(|e| e.to_string())?;
            assert_block_equals_cycles(&mut an_a, &mut an_b, &codes, &lane_counts)?;
            Ok(())
        },
    );
}

#[test]
fn prop_service_admission_invariants_hold_under_arbitrary_interleavings() {
    // Drive the admission core through random submit / dispatch /
    // complete / cancel interleavings over random tenant sets.  After
    // EVERY step: no tenant exceeds its quota, the queue never exceeds
    // its bound, total admitted work equals queued + in-flight +
    // terminal, and the counters conserve submissions.
    check_with(
        "service admission invariants",
        Config { cases: 40, max_size: 24, seed: 0x5E71 },
        |c| {
            let ntenants = 1 + c.rng.below(4) as usize;
            let bound = c.rng.below(8) as usize;
            let tenants: Vec<(TenantId, TenantSpec)> = (0..ntenants)
                .map(|i| {
                    (
                        TenantId(i as u32),
                        TenantSpec {
                            weight: 1 + c.rng.below(5) as u32,
                            quota: c.rng.below(6) as usize,
                        },
                    )
                })
                .collect();
            let cfg = ServiceConfig {
                queue_bound: bound,
                tenants: tenants.clone(),
                default_tenant: TenantSpec::default(),
            };
            let mut core = SchedCore::new(&cfg);
            let mut queued: Vec<Ticket> = Vec::new();
            let mut running: Vec<Ticket> = Vec::new();
            for step in 0..20 + c.rng.below(80) {
                match c.rng.below(5) {
                    0 | 1 => {
                        let t = TenantId(c.rng.below(ntenants as u64) as u32);
                        if let Ok(ticket) = core.submit(t) {
                            queued.push(ticket);
                        }
                    }
                    2 => {
                        if let Some(ticket) = core.next() {
                            queued.retain(|q| q.seq != ticket.seq);
                            running.push(ticket);
                        }
                    }
                    3 => {
                        if !running.is_empty() {
                            let i = c.rng.below(running.len() as u64) as usize;
                            let t = running.swap_remove(i);
                            let out = if c.rng.below(4) == 0 {
                                Outcome::Failed
                            } else {
                                Outcome::Done
                            };
                            core.complete(t.tenant, out);
                        }
                    }
                    _ => {
                        if !queued.is_empty() {
                            let i = c.rng.below(queued.len() as u64) as usize;
                            let t = queued.swap_remove(i);
                            core.cancel_queued(t);
                        }
                    }
                }
                prop_assert!(
                    core.queued_len() <= bound,
                    "step {step}: queue {} exceeds bound {bound}",
                    core.queued_len()
                );
                for (id, spec) in &tenants {
                    prop_assert!(
                        core.outstanding(*id) <= spec.quota,
                        "step {step}: {id} outstanding {} exceeds quota {}",
                        core.outstanding(*id),
                        spec.quota
                    );
                }
                let k = core.counters();
                prop_assert_eq!(
                    k.submitted,
                    k.admitted + k.rejected_full + k.rejected_quota + k.rejected_shutdown
                );
                prop_assert_eq!(
                    k.admitted,
                    (core.queued_len() + core.in_flight()) as u64 + k.terminal()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_traffic_reports_are_pure_functions_of_the_seed() {
    // The virtual-clock harness is deterministic end to end: any random
    // scenario (seed, load shape, pool count) replays to a bit-identical
    // report — latency percentiles included — and its counters conserve
    // every admitted job to quiescence.
    check_with(
        "traffic report determinism",
        Config { cases: 6, max_size: 12, seed: 0x5E72 },
        |c| {
            let model = PerfModel::paper();
            let mut cfg = TrafficConfig::paper(c.rng.next_u64());
            for load in &mut cfg.tenants {
                load.jobs = 8 + c.rng.below(16) as usize;
                load.mean_gap = 10_000 + c.rng.below(80_000);
            }
            cfg.pools = 1 + c.rng.below(3) as usize;
            cfg.queue_bound = 1 + c.rng.below(48) as usize;
            let a = cfg.run(&model).map_err(|e| e.to_string())?;
            let b = cfg.run(&model).map_err(|e| e.to_string())?;
            prop_assert!(a == b, "same-seed traffic reports diverged");
            for (x, y) in [
                (a.wait_p50, b.wait_p50),
                (a.wait_p95, b.wait_p95),
                (a.wait_p99, b.wait_p99),
                (a.total_p99, b.total_p99),
            ] {
                prop_assert_eq!(x.to_bits(), y.to_bits());
            }
            let k = &a.counters;
            prop_assert_eq!(
                k.submitted,
                k.admitted + k.rejected_full + k.rejected_quota + k.rejected_shutdown
            );
            // The sim runs to quiescence with no cancels: every admitted
            // job completes.
            prop_assert_eq!(k.admitted, k.terminal());
            prop_assert_eq!(k.completed, k.admitted);
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_dense_mttkrp_agree() {
    check_with(
        "sparse == dense MTTKRP",
        Config { cases: 20, max_size: 16, seed: 0xE5 },
        |c| {
            let shape = rand_shape(c, 10);
            let nnz = c.rng.below(100) as usize;
            let coo = CooTensor::random(&shape, nnz, &mut c.rng);
            let dense = coo.to_dense();
            let r = 1 + c.rng.below(6) as usize;
            let factors: Vec<Matrix> =
                shape.iter().map(|&d| Matrix::randn(d, r, &mut c.rng)).collect();
            for mode in 0..3 {
                let a = psram_imc::mttkrp::sparse_mttkrp(&coo, &factors, mode).unwrap();
                let b = dense_mttkrp(&dense, &factors, mode).unwrap();
                for (x, y) in a.data().iter().zip(b.data()) {
                    prop_assert!((x - y).abs() < 1e-3, "{x} vs {y}");
                }
            }
            Ok(())
        },
    );
}
