//! Regression tests pinning the predictive performance model to the
//! paper's published numbers (§V.B, Fig. 5).  No artifacts needed.

use psram_imc::perfmodel::{
    fig5_frequency, fig5_wavelengths, headline, PerfModel, Workload,
};
use psram_imc::telemetry::BenchReport;

/// §V.B: peak = 2 × total_words × wavelengths × clock
///             = 2 × 8192 × 52 × 20 GHz ≈ 17.04 PetaOps.
#[test]
fn paper_headline_peak_is_17_04_petaops() {
    let m = PerfModel::paper();
    assert_eq!(m.geom.total_words(), 8192);
    assert_eq!(m.wavelengths, 52);
    assert_eq!(m.clock_hz, 20e9);
    let explicit = 2.0 * 8192.0 * 52.0 * 20e9;
    assert_eq!(m.peak_ops(), explicit);
    assert!(
        (m.peak_ops() / 1e15 - 17.04).abs() < 0.005,
        "peak = {:.4} PetaOps",
        m.peak_ops() / 1e15
    );
}

/// The headline driver agrees with the model and sustains near peak on the
/// paper's 1M-per-mode workload.
#[test]
fn headline_driver_consistent() {
    let (peak, sustained, util) = headline().unwrap();
    assert_eq!(peak, PerfModel::paper().peak_ops());
    assert!(sustained <= peak);
    assert!(util > 0.98 && util <= 1.0, "util = {util}");
}

/// The committed telemetry baseline (`BENCH_headline.json` at the repo
/// root) carries the same paper numbers the model computes live: the
/// 17.04-PetaOps pin holds on the *file*, sustained stays below peak, and
/// the committed values are bit-equal to `PerfModel::paper()` /
/// `headline()` — a drift in either the model or the baseline fails here
/// before CI's diff job ever runs.
#[test]
fn committed_headline_baseline_matches_live_model() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_headline.json");
    let report = BenchReport::read_file(&path).unwrap();
    let peak = report.value("headline.peak_ops").unwrap();
    let sustained = report.value("headline.sustained_ops").unwrap();
    assert!(
        (peak / 1e15 - 17.04).abs() < 0.005,
        "committed peak = {:.4} PetaOps",
        peak / 1e15
    );
    assert!(sustained <= peak);
    assert_eq!(peak, PerfModel::paper().peak_ops());
    let (live_peak, live_sustained, _) = headline().unwrap();
    assert_eq!(peak, live_peak);
    assert_eq!(sustained, live_sustained);
}

/// Sustained performance can never exceed peak, for every configuration
/// the Fig. 5 sweeps touch — wavelengths × frequencies, with and without
/// double buffering, across array counts.
#[test]
fn sustained_never_exceeds_peak_across_sweeps() {
    let channels = [1usize, 2, 4, 8, 12, 16, 24, 32, 40, 52, 64];
    let clocks = [1e9, 2e9, 5e9, 8e9, 10e9, 12e9, 15e9, 18e9, 20e9, 25e9];
    let workloads = [
        Workload::paper_large(),
        Workload { i_rows: 52, k_contraction: 256, rank: 32 },
        Workload { i_rows: 1000, k_contraction: 10_000, rank: 17 },
    ];
    for &l in &channels {
        for &f in &clocks {
            for &db in &[false, true] {
                for &arrays in &[1usize, 4, 16] {
                    for w in &workloads {
                        let mut m = PerfModel::paper();
                        m.wavelengths = l;
                        m.clock_hz = f;
                        m.double_buffer = db;
                        m.num_arrays = arrays;
                        let est = m.predict(w).unwrap();
                        let peak = m.peak_ops();
                        assert!(
                            est.sustained_raw_ops <= peak * (1.0 + 1e-12),
                            "sustained {} > peak {} (λ={l} f={f} db={db} arrays={arrays})",
                            est.sustained_raw_ops,
                            peak
                        );
                        assert!(est.sustained_useful_ops <= est.sustained_raw_ops);
                        assert!(est.utilization > 0.0 && est.utilization <= 1.0);
                        assert!(
                            est.padding_efficiency > 0.0
                                && est.padding_efficiency <= 1.0
                        );
                    }
                }
            }
        }
    }
}

/// The Fig. 5 sweep drivers themselves respect the peak bound at every
/// point (the series the benches print).
#[test]
fn fig5_sweep_points_within_peak() {
    let pts = fig5_wavelengths(&[1, 2, 4, 8, 16, 32, 52, 64], 20e9).unwrap();
    for p in &pts {
        let mut m = PerfModel::paper();
        m.wavelengths = p.x as usize;
        assert!(p.sustained_ops <= m.peak_ops() * (1.0 + 1e-12));
    }
    let pts = fig5_frequency(&[1e9, 5e9, 10e9, 20e9, 25e9], 52).unwrap();
    for p in &pts {
        let mut m = PerfModel::paper();
        m.clock_hz = p.x;
        assert!(p.sustained_ops <= m.peak_ops() * (1.0 + 1e-12));
    }
}
